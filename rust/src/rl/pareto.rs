//! Pareto archive (§3.10 "Pareto-based final selection", §5.4).
//!
//! Every feasible configuration enters the archive; dominated points are
//! evicted. After convergence the final design is selected from the
//! frontier by scalarizing frontier-normalized objectives with the user's
//! PPA weights — guaranteeing the returned design is Pareto-optimal.

use crate::ppa::PpaWeights;

/// One archived operating point. Objectives: maximize perf, minimize
/// power, minimize area.
#[derive(Debug, Clone)]
pub struct ParetoPoint {
    pub perf_gops: f64,
    pub power_mw: f64,
    pub area_mm2: f64,
    pub tokens_per_s: f64,
    /// Episode that produced this point (provenance).
    pub episode: usize,
    /// Opaque payload id (index into the caller's config log).
    pub tag: usize,
}

impl ParetoPoint {
    /// True when `self` dominates `other` (≥ on all, > on at least one,
    /// with perf maximized and power/area minimized).
    pub fn dominates(&self, other: &ParetoPoint) -> bool {
        let ge = self.perf_gops >= other.perf_gops
            && self.power_mw <= other.power_mw
            && self.area_mm2 <= other.area_mm2;
        let gt = self.perf_gops > other.perf_gops
            || self.power_mw < other.power_mw
            || self.area_mm2 < other.area_mm2;
        ge && gt
    }

    /// Energy per generated token in mJ (power_mw = mJ/s over tokens/s).
    /// The scenario-robust efficiency objective of the atlas sweep: raw
    /// power is NOT monotone under batch amortization (the NoC term
    /// scales with tokens/s), but energy/token is — static power
    /// amortizes over more tokens and NoC energy per token depends only
    /// on the placement (DESIGN.md §12).
    pub fn energy_mj_per_token(&self) -> f64 {
        if self.tokens_per_s <= 0.0 {
            f64::INFINITY
        } else {
            self.power_mw / self.tokens_per_s
        }
    }

    /// Dominance in (perf ↑, energy/token ↓, area ↓) space — the merge
    /// objective of the scenario atlas (DESIGN.md §12).
    pub fn dominates_energy(&self, other: &ParetoPoint) -> bool {
        let (se, oe) = (self.energy_mj_per_token(), other.energy_mj_per_token());
        let ge = self.perf_gops >= other.perf_gops
            && se <= oe
            && self.area_mm2 <= other.area_mm2;
        let gt = self.perf_gops > other.perf_gops || se < oe || self.area_mm2 < other.area_mm2;
        ge && gt
    }

    /// Weak energy-space dominance: `dominates_energy` or an exact
    /// component-wise tie. The atlas soundness test accepts a tie — a
    /// neighbor that achieved the *identical* operating point covers it.
    pub fn covers_energy(&self, other: &ParetoPoint) -> bool {
        self.perf_gops >= other.perf_gops
            && self.energy_mj_per_token() <= other.energy_mj_per_token()
            && self.area_mm2 <= other.area_mm2
    }
}

#[derive(Debug, Clone, Default)]
pub struct ParetoArchive {
    points: Vec<ParetoPoint>,
}

impl ParetoArchive {
    pub fn new() -> Self {
        Self::default()
    }

    /// Insert if non-dominated; evict anything the new point dominates.
    /// Returns true if inserted.
    pub fn insert(&mut self, p: ParetoPoint) -> bool {
        if self.points.iter().any(|q| q.dominates(&p)) {
            return false;
        }
        self.points.retain(|q| !p.dominates(q));
        self.points.push(p);
        true
    }

    pub fn frontier(&self) -> &[ParetoPoint] {
        &self.points
    }

    /// Rebuild an archive from a serialized frontier, preserving storage
    /// order verbatim (no re-insertion): `frontier()` of the restored
    /// archive is bit-identical to the snapshot, which the checkpoint
    /// resume-determinism contract relies on.
    pub fn from_points(points: Vec<ParetoPoint>) -> ParetoArchive {
        ParetoArchive { points }
    }

    /// Merge another archive into this one by re-inserting its frontier
    /// in storage order. Insertion order only affects internal layout,
    /// never frontier membership, but keeping it fixed makes parallel
    /// drivers reproduce serial archives exactly: workers' archives are
    /// merged in input (seed/node) order, not completion order.
    pub fn merge(&mut self, other: &ParetoArchive) {
        for p in other.frontier() {
            self.insert(p.clone());
        }
    }

    pub fn len(&self) -> usize {
        self.points.len()
    }

    pub fn is_empty(&self) -> bool {
        self.points.is_empty()
    }

    /// Scalarized selection over frontier-normalized objectives with the
    /// user PPA weights (lower composite = better, matching the paper's
    /// lower-is-better score convention).
    pub fn select(&self, w: &PpaWeights) -> Option<&ParetoPoint> {
        if self.points.is_empty() {
            return None;
        }
        let (alpha, beta, gamma) = w.normalized();
        let fmax = |f: fn(&ParetoPoint) -> f64| {
            self.points.iter().map(f).fold(f64::MIN, f64::max)
        };
        let fmin = |f: fn(&ParetoPoint) -> f64| {
            self.points.iter().map(f).fold(f64::MAX, f64::min)
        };
        let (p_lo, p_hi) = (fmin(|p| p.perf_gops), fmax(|p| p.perf_gops));
        let (w_lo, w_hi) = (fmin(|p| p.power_mw), fmax(|p| p.power_mw));
        let (a_lo, a_hi) = (fmin(|p| p.area_mm2), fmax(|p| p.area_mm2));
        let nz = |v: f64, lo: f64, hi: f64| {
            if hi - lo < 1e-12 {
                0.5
            } else {
                (v - lo) / (hi - lo)
            }
        };
        self.points.iter().min_by(|a, b| {
            let sa = alpha * (1.0 - nz(a.perf_gops, p_lo, p_hi))
                + beta * nz(a.power_mw, w_lo, w_hi)
                + gamma * nz(a.area_mm2, a_lo, a_hi);
            let sb = alpha * (1.0 - nz(b.perf_gops, p_lo, p_hi))
                + beta * nz(b.power_mw, w_lo, w_hi)
                + gamma * nz(b.area_mm2, a_lo, a_hi);
            sa.total_cmp(&sb)
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn p(perf: f64, power: f64, area: f64, tag: usize) -> ParetoPoint {
        ParetoPoint {
            perf_gops: perf,
            power_mw: power,
            area_mm2: area,
            tokens_per_s: perf / 10.0,
            episode: 0,
            tag,
        }
    }

    #[test]
    fn dominated_points_rejected() {
        let mut a = ParetoArchive::new();
        assert!(a.insert(p(100.0, 10.0, 10.0, 0)));
        assert!(!a.insert(p(90.0, 11.0, 11.0, 1))); // dominated
        assert_eq!(a.len(), 1);
    }

    #[test]
    fn dominating_point_evicts() {
        let mut a = ParetoArchive::new();
        a.insert(p(100.0, 10.0, 10.0, 0));
        a.insert(p(50.0, 5.0, 5.0, 1)); // trade-off: kept
        assert_eq!(a.len(), 2);
        assert!(a.insert(p(120.0, 4.0, 4.0, 2))); // dominates both
        assert_eq!(a.len(), 1);
        assert_eq!(a.frontier()[0].tag, 2);
    }

    #[test]
    fn frontier_holds_tradeoffs() {
        let mut a = ParetoArchive::new();
        a.insert(p(100.0, 50.0, 10.0, 0)); // fast, hungry
        a.insert(p(10.0, 1.0, 10.0, 1)); // slow, frugal
        a.insert(p(50.0, 20.0, 5.0, 2)); // compact
        assert_eq!(a.len(), 3);
    }

    #[test]
    fn selection_follows_weights() {
        let mut a = ParetoArchive::new();
        a.insert(p(100.0, 50.0, 10.0, 0));
        a.insert(p(10.0, 1.0, 10.0, 1));
        // performance-priority picks the fast point
        let hp = a.select(&PpaWeights { perf: 0.8, power: 0.1, area: 0.1 }).unwrap();
        assert_eq!(hp.tag, 0);
        // power-priority picks the frugal point
        let lp = a.select(&PpaWeights { perf: 0.1, power: 0.8, area: 0.1 }).unwrap();
        assert_eq!(lp.tag, 1);
    }

    #[test]
    fn selected_point_is_pareto_optimal() {
        let mut a = ParetoArchive::new();
        for i in 0..20 {
            let f = i as f64;
            a.insert(p(10.0 * f, 5.0 * f + 1.0, 100.0 - 2.0 * f, i));
        }
        let sel = a.select(&PpaWeights::HIGH_PERF).unwrap().clone();
        assert!(!a.frontier().iter().any(|q| q.dominates(&sel)));
    }

    #[test]
    fn energy_dominance_tracks_mj_per_token() {
        // same raw power, but a dominates in tokens/s → lower mJ/token
        let mut a = p(100.0, 50.0, 10.0, 0);
        a.tokens_per_s = 1000.0;
        let mut b = p(100.0, 50.0, 10.0, 1);
        b.tokens_per_s = 500.0;
        assert!(a.energy_mj_per_token() < b.energy_mj_per_token());
        assert!(a.dominates_energy(&b));
        assert!(!b.dominates_energy(&a));
        // raw-power dominance sees them as tied on every axis
        assert!(!a.dominates(&b));
        // covers_energy admits the exact tie, dominates_energy does not
        assert!(a.covers_energy(&a.clone()));
        assert!(!a.dominates_energy(&a.clone()));
    }

    #[test]
    fn zero_token_point_has_infinite_energy() {
        let mut z = p(0.0, 10.0, 10.0, 0);
        z.tokens_per_s = 0.0;
        assert!(z.energy_mj_per_token().is_infinite());
        let live = p(1.0, 10.0, 10.0, 1);
        assert!(live.dominates_energy(&z));
    }

    #[test]
    fn equal_points_not_mutually_dominating() {
        let a = p(10.0, 10.0, 10.0, 0);
        let b = p(10.0, 10.0, 10.0, 1);
        assert!(!a.dominates(&b));
        assert!(!b.dominates(&a));
    }

    #[test]
    fn merge_equals_sequential_insertion() {
        let pts: Vec<ParetoPoint> =
            (0..12).map(|i| p(10.0 * i as f64, 40.0 - 3.0 * i as f64, 20.0, i)).collect();
        let mut sequential = ParetoArchive::new();
        for q in &pts {
            sequential.insert(q.clone());
        }
        // split into two worker archives, then merge in worker order
        let (mut w1, mut w2) = (ParetoArchive::new(), ParetoArchive::new());
        for (i, q) in pts.iter().enumerate() {
            if i < 6 {
                w1.insert(q.clone());
            } else {
                w2.insert(q.clone());
            }
        }
        let mut merged = ParetoArchive::new();
        merged.merge(&w1);
        merged.merge(&w2);
        assert_eq!(merged.len(), sequential.len());
        let mut tags_a: Vec<usize> = merged.frontier().iter().map(|q| q.tag).collect();
        let mut tags_b: Vec<usize> =
            sequential.frontier().iter().map(|q| q.tag).collect();
        tags_a.sort_unstable();
        tags_b.sort_unstable();
        assert_eq!(tags_a, tags_b);
    }
}
