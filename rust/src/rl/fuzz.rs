//! Randomized differential equivalence harness (DESIGN.md §14).
//!
//! The codebase's correctness story is a stack of *equivalence
//! contracts*: two execution strategies that must produce identical
//! results (serial vs parallel scoring, staged vs fresh scratch, pruned
//! vs exact argmax, B-lane vec-env vs B serial runs, pinned learner vs
//! inline, kill→resume vs uninterrupted) plus one tolerance contract
//! (SIMD vs scalar kernels). The golden suites pin each contract at a
//! handful of configs; this module is the translation-validation layer
//! that checks them at *arbitrary* points of the config space:
//!
//! * [`CaseGen`] — a seeded generator of valid [`FuzzCase`]s (any
//!   registry workload × node × phase/seq_len/batch scenario × knob
//!   combo: lanes, learner mode, prune, eval_cache, kv strategy,
//!   checkpoint/crash cadence).
//! * [`ORACLES`] — the equivalence-class registry. Each oracle runs its
//!   paired executions and reports the **first diverging artifact** as a
//!   structured [`Mismatch`] (episode-log slot, frontier point, replay
//!   index, tensor element, scalar counter).
//! * [`shrink_with`] — a delta-debugging shrinker that minimizes a
//!   failing case along each axis (episodes, lanes, rounds, batch,
//!   scenario, knobs toward defaults) to a minimal reproducer, emitted
//!   as a ready-to-paste `silicon-rl fuzz` command line
//!   ([`FuzzCase::cmd_line`]) plus a serialized repro file
//!   ([`FuzzCase::to_repro`] / [`FuzzCase::from_repro`]).
//!
//! Kernel-path note: the `simd-scalar` oracle flips the process-global
//! kernel dispatch around each kernel call. By the repo convention only
//! `tests/kernel_parity.rs` may do that from a test binary, so
//! `tests/fuzz_equivalence.rs` excludes that class — it runs from the
//! `silicon-rl fuzz` CLI (its own process) instead. Every other oracle
//! keeps `kernels=scalar`, the bit-exact reference.

use std::fmt;

use crate::config::{Granularity, ModeConfig, RunConfig, Workload};
use crate::env::{Action, ACT_DIM, SAC_STATE_DIM};
use crate::error::{Error, Result};
use crate::eval::{self, EvalOutcome, EvalScratch, Evaluator};
use crate::ir::registry;
use crate::kv::KvStrategy;
use crate::nn::backend;
use crate::nn::kernels::{self, KernelSel};
use crate::nn::math;
use crate::rl::checkpoint::INJECTED_CRASH_MSG;
use crate::rl::learner::LearnerMode;
use crate::rl::multiseed::derive_seed;
use crate::rl::per::{PerBuffer, Transition};
use crate::rl::{self, LaneSpec, NodeResult, SacAgent};
use crate::util::Rng;

/// Store-init seed shared by every paired execution (the convention of
/// every golden suite: `SacAgent::new(..., &mut Rng::new(42))`).
const AGENT_INIT_SEED: u64 = 42;

// ---------------------------------------------------------------- mismatch

/// The first diverging artifact of a failed paired execution.
#[derive(Debug, Clone, PartialEq)]
pub enum Artifact {
    /// Episode-log slot: `lane` is the job index (0 for single-run
    /// oracles), `episode` the log position, `field` the column.
    Episode { lane: usize, episode: usize, field: &'static str },
    /// Pareto-frontier point (index in frontier order).
    Frontier { lane: usize, index: usize, field: &'static str },
    /// Replay-buffer slot (vec interleave order: `t·B + lane`).
    Replay { slot: usize, field: &'static str },
    /// Tensor element (evaluator outcome field or kernel output).
    Tensor { name: String, index: usize },
    /// A scalar summary (argmax index, counter, best episode, ...).
    Scalar { name: String },
}

impl fmt::Display for Artifact {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Artifact::Episode { lane, episode, field } => {
                write!(f, "episode log lane {lane} ep {episode} field {field}")
            }
            Artifact::Frontier { lane, index, field } => {
                write!(f, "frontier lane {lane} point {index} field {field}")
            }
            Artifact::Replay { slot, field } => {
                write!(f, "replay slot {slot} field {field}")
            }
            Artifact::Tensor { name, index } => write!(f, "tensor {name}[{index}]"),
            Artifact::Scalar { name } => write!(f, "scalar {name}"),
        }
    }
}

/// Structured report of one equivalence violation: which oracle, which
/// artifact diverged first, and both sides' values.
#[derive(Debug, Clone)]
pub struct Mismatch {
    pub oracle: &'static str,
    pub artifact: Artifact,
    /// Left/right side values, formatted (left = reference execution).
    pub left: String,
    pub right: String,
}

impl fmt::Display for Mismatch {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "[{}] first divergence at {}: {} != {}",
            self.oracle, self.artifact, self.left, self.right
        )
    }
}

// -------------------------------------------------------------- fuzz case

/// One generated test point: an oracle name plus the full `RunConfig`
/// and the oracle-local knobs (candidate-batch width, walk rounds, and
/// the action-stream seed, decoupled from `cfg.seed`).
#[derive(Debug, Clone)]
pub struct FuzzCase {
    pub oracle: &'static str,
    pub cfg: RunConfig,
    /// Candidate-batch width for the evaluator-layer oracles.
    pub batch: usize,
    /// Mesh-walk rounds for the evaluator-layer oracles.
    pub rounds: usize,
    /// Seed of the random action/shape stream.
    pub action_seed: u64,
}

fn kv_key(kv: &KvStrategy) -> Option<String> {
    match kv {
        KvStrategy::Full => Some("full".into()),
        KvStrategy::Quantized { bits: 8 } => Some("int8".into()),
        KvStrategy::Quantized { bits: 4 } => Some("int4".into()),
        KvStrategy::Window { tokens } => Some(format!("window:{tokens}")),
        KvStrategy::QuantizedWindow { bits: 8, tokens } => {
            Some(format!("int8win:{tokens}"))
        }
        _ => None,
    }
}

fn learner_key(mode: LearnerMode) -> &'static str {
    match mode {
        LearnerMode::Inline => "inline",
        LearnerMode::Pinned => "pinned",
        LearnerMode::Async => "async",
    }
}

impl FuzzCase {
    /// Serialize as `key = value` lines loadable by
    /// `silicon-rl fuzz repro=FILE` (and by [`FuzzCase::from_repro`]).
    /// Only contract-relevant keys are written; everything else is the
    /// `RunConfig` default, re-imposed by [`sanitize`] on load.
    pub fn to_repro(&self) -> String {
        let mut out = String::from("# silicon-rl fuzz reproducer\n");
        for (k, v) in self.kv_pairs() {
            out.push_str(&format!("{k} = {v}\n"));
        }
        out
    }

    /// Ready-to-paste CLI line reproducing this case.
    pub fn cmd_line(&self) -> String {
        let mut out = String::from("silicon-rl fuzz");
        for (k, v) in self.kv_pairs() {
            out.push_str(&format!(" {k}={v}"));
        }
        out
    }

    /// Canonical identity of the case — equal fingerprints mean the
    /// same paired executions run.
    pub fn fingerprint(&self) -> String {
        self.cmd_line()
    }

    fn kv_pairs(&self) -> Vec<(&'static str, String)> {
        let cfg = &self.cfg;
        let mut kv: Vec<(&'static str, String)> =
            vec![("oracle", self.oracle.to_string())];
        if cfg.workload.name() != RunConfig::default().workload.name() {
            kv.push(("workload", cfg.workload.name().to_string()));
        }
        kv.push(("phase", cfg.phase.name().to_string()));
        if let Some(n) = cfg.seq_len {
            kv.push(("seq_len", n.to_string()));
        }
        if let Some(n) = cfg.batch {
            kv.push(("batch", n.to_string()));
        }
        if cfg.mode.name == "low-power" {
            kv.push(("mode", "lp".into()));
        }
        if let Some(s) = kv_key(&cfg.kv_strategy) {
            if s != "full" {
                kv.push(("kv", s));
            }
        }
        let nodes: Vec<String> = cfg.nodes_nm.iter().map(|n| n.to_string()).collect();
        kv.push(("nodes", nodes.join(",")));
        kv.push(("seed", cfg.seed.to_string()));
        kv.push(("episodes", cfg.rl.episodes_per_node.to_string()));
        kv.push(("warmup", cfg.rl.warmup_steps.to_string()));
        if cfg.rl.lanes != 0 {
            kv.push(("lanes", cfg.rl.lanes.to_string()));
        }
        if !matches!(cfg.rl.learner, LearnerMode::Inline) {
            kv.push(("learner", learner_key(cfg.rl.learner).into()));
        }
        kv.push(("prune", if cfg.rl.prune { "true" } else { "false" }.into()));
        kv.push(("eval_cache", cfg.rl.eval_cache.to_string()));
        if cfg.rl.checkpoint_every != 0 {
            kv.push(("checkpoint_every", cfg.rl.checkpoint_every.to_string()));
        }
        if cfg.rl.crash_after != 0 {
            kv.push(("crash_after", cfg.rl.crash_after.to_string()));
        }
        kv.push(("fuzz_batch", self.batch.to_string()));
        kv.push(("fuzz_rounds", self.rounds.to_string()));
        kv.push(("fuzz_action_seed", self.action_seed.to_string()));
        kv
    }

    /// Build a case from an oracle name plus `key=value` pairs (the
    /// `fuzz_*` keys are harness-local; the rest go through
    /// `RunConfig::apply`). The result is [`sanitize`]d.
    pub fn from_kv(oracle: &str, pairs: &[(String, String)]) -> Result<FuzzCase> {
        let oracle = oracle_by_name(oracle)
            .ok_or_else(|| {
                Error::msg(format!(
                    "unknown oracle {oracle}; registered: {}",
                    class_names().join(", ")
                ))
            })?
            .name;
        let mut case = FuzzCase {
            oracle,
            cfg: RunConfig::default(),
            batch: 6,
            rounds: 2,
            action_seed: 1,
        };
        for (k, v) in pairs {
            match k.as_str() {
                "fuzz_batch" => {
                    case.batch =
                        v.parse().map_err(|_| Error::msg("bad fuzz_batch"))?
                }
                "fuzz_rounds" => {
                    case.rounds =
                        v.parse().map_err(|_| Error::msg("bad fuzz_rounds"))?
                }
                "fuzz_action_seed" => {
                    case.action_seed =
                        v.parse().map_err(|_| Error::msg("bad fuzz_action_seed"))?
                }
                _ => case.cfg.apply(k, v).map_err(Error::msg)?,
            }
        }
        sanitize(&mut case);
        Ok(case)
    }

    /// Parse a repro file produced by [`FuzzCase::to_repro`].
    pub fn from_repro(text: &str) -> Result<FuzzCase> {
        let mut oracle = None;
        let mut pairs = Vec::new();
        for (i, line) in text.lines().enumerate() {
            let line = line.split('#').next().unwrap().trim();
            if line.is_empty() {
                continue;
            }
            let (k, v) = line
                .split_once('=')
                .ok_or_else(|| Error::msg(format!("repro line {}: not key = value", i + 1)))?;
            let (k, v) = (k.trim(), v.trim());
            if k == "oracle" {
                oracle = Some(v.to_string());
            } else {
                pairs.push((k.to_string(), v.to_string()));
            }
        }
        let oracle = oracle.ok_or_else(|| Error::msg("repro file has no `oracle =` line"))?;
        FuzzCase::from_kv(&oracle, &pairs)
    }
}

// --------------------------------------------------------------- sanitize

/// Number of fault-injection probes a full run of this case fires
/// (3 per vec step — A after the periodic save, B after the env
/// fan-out, C after the replay insert — times steps per wave, times
/// waves; the harness generates exactly `lanes` jobs, so one wave).
fn probe_count(case: &FuzzCase) -> u64 {
    3 * case.cfg.rl.episodes_per_node as u64
}

/// Force a proposed case into its oracle's validity envelope. Applied
/// by the generator, after every shrink proposal, and on repro load —
/// so arbitrary mutations stay runnable by construction. Deterministic
/// and idempotent.
pub fn sanitize(case: &mut FuzzCase) {
    let cfg = &mut case.cfg;
    // fixed execution substrate: the harness compares library results,
    // not backends, and never touches AOT artifacts
    cfg.backend = crate::nn::BackendSel::Native;
    cfg.artifacts_dir = "/nonexistent-artifacts".into();
    cfg.granularity = Granularity::Group;
    cfg.kernels = KernelSel::Scalar;
    cfg.parallel_nodes = false;
    cfg.resume = None;
    cfg.rl.learner_fail_after = 0;
    if kv_key(&cfg.kv_strategy).is_none() {
        cfg.kv_strategy = KvStrategy::Full;
    }
    // nodes must carry a mode budget: snap to the ladder, cap the list
    const LADDER: [u32; 7] = [3, 5, 7, 10, 14, 22, 28];
    if cfg.nodes_nm.is_empty() {
        cfg.nodes_nm = vec![7];
    }
    cfg.nodes_nm.truncate(2);
    for nm in &mut cfg.nodes_nm {
        if !LADDER.contains(nm) {
            *nm = *LADDER
                .iter()
                .min_by_key(|l| l.abs_diff(*nm))
                .expect("ladder is non-empty");
        }
    }
    cfg.rl.episodes_per_node = cfg.rl.episodes_per_node.clamp(1, 128);
    cfg.rl.lanes = cfg.rl.lanes.clamp(1, 8);
    case.batch = case.batch.clamp(1, 16);
    case.rounds = case.rounds.clamp(1, 4);
    match case.oracle {
        "serial-parallel" | "staged-fresh" | "pruned-exact" | "simd-scalar" => {
            cfg.rl.lanes = 1;
            cfg.rl.learner = LearnerMode::Inline;
            cfg.rl.checkpoint_every = 0;
            cfg.rl.crash_after = 0;
            if case.oracle == "pruned-exact" {
                case.batch = case.batch.max(2);
            }
        }
        "cache-nocache" => {
            cfg.rl.lanes = 1;
            cfg.rl.learner = LearnerMode::Inline;
            cfg.rl.checkpoint_every = 0;
            cfg.rl.crash_after = 0;
            cfg.rl.eval_cache = cfg.rl.eval_cache.clamp(16, 4096);
        }
        "vec-serial" => {
            // B-lane ≡ B-serial is a rollout-only contract: live updates
            // amortize on the shared step counter and legitimately
            // diverge from B independent serial schedules
            cfg.rl.lanes = cfg.rl.lanes.max(2);
            cfg.rl.warmup_steps = 10_000;
            cfg.rl.learner = LearnerMode::Inline;
            cfg.rl.checkpoint_every = 0;
            cfg.rl.crash_after = 0;
        }
        "pinned-inline" => {
            // the oracle runs learner=inline vs learner=pinned itself
            cfg.rl.checkpoint_every = 0;
            cfg.rl.crash_after = 0;
        }
        "crash-resume" => {
            if matches!(cfg.rl.learner, LearnerMode::Async) {
                // async trades determinism for throughput; resume
                // identity is only contracted for inline/pinned
                cfg.rl.learner = LearnerMode::Inline;
            }
            cfg.rl.checkpoint_every = cfg.rl.checkpoint_every.clamp(1, 64);
            let probes = probe_count(case);
            cfg.rl.crash_after = cfg.rl.crash_after.clamp(1, probes);
        }
        _ => {}
    }
}

// -------------------------------------------------------------- generator

/// All registered equivalence classes, in registry order.
pub fn class_names() -> Vec<&'static str> {
    ORACLES.iter().map(|o| o.name).collect()
}

/// Seeded generator of valid fuzz cases: same seed → the same case
/// sequence, bit-for-bit (pinned by `tests/fuzz_equivalence.rs`).
pub struct CaseGen {
    rng: Rng,
    classes: Vec<&'static str>,
}

impl CaseGen {
    /// `classes` selects which oracles to draw from (resolved against
    /// the registry; unknown names are an error).
    pub fn new(seed: u64, classes: &[&str]) -> Result<CaseGen> {
        let mut resolved = Vec::new();
        for c in classes {
            let o = oracle_by_name(c).ok_or_else(|| {
                Error::msg(format!(
                    "unknown fuzz class {c}; registered: {}",
                    class_names().join(", ")
                ))
            })?;
            resolved.push(o.name);
        }
        if resolved.is_empty() {
            return Err(Error::msg("fuzz needs at least one class"));
        }
        Ok(CaseGen { rng: Rng::new(seed).fork(FUZZ_STREAM_TAG), classes: resolved })
    }

    pub fn next_case(&mut self) -> FuzzCase {
        let r = &mut self.rng;
        let oracle = self.classes[r.below(self.classes.len())];
        let mut cfg = RunConfig::default();

        // workload × scenario axes
        let names = registry::names();
        cfg.workload = Workload::parse(names[r.below(names.len())])
            .expect("registry names always parse");
        cfg.phase = if r.below(2) == 0 {
            crate::ir::spec::Phase::Decode
        } else {
            crate::ir::spec::Phase::Prefill
        };
        cfg.seq_len = [None, Some(128), Some(512), Some(2048), Some(8192)][r.below(5)];
        cfg.batch = [None, Some(1), Some(2), Some(4)][r.below(4)];
        if r.below(4) == 0 {
            cfg.mode = ModeConfig::low_power();
        }
        cfg.kv_strategy = match r.below(5) {
            0 => KvStrategy::Full,
            1 => KvStrategy::Quantized { bits: 8 },
            2 => KvStrategy::Quantized { bits: 4 },
            3 => KvStrategy::Window { tokens: 256 },
            _ => KvStrategy::QuantizedWindow { bits: 8, tokens: 512 },
        };

        // node lanes
        const LADDER: [u32; 7] = [3, 5, 7, 10, 14, 22, 28];
        let n0 = LADDER[r.below(7)];
        cfg.nodes_nm = if r.below(3) == 0 {
            let n1 = LADDER[r.below(7)];
            if n1 == n0 {
                vec![n0]
            } else {
                vec![n0, n1]
            }
        } else {
            vec![n0]
        };
        cfg.seed = (r.next_u64() & 0xFFFF) | 1;

        // engine knobs
        cfg.rl.prune = r.below(2) == 0;
        cfg.prune_explicit = true;
        cfg.rl.eval_cache = [0usize, 64, 256][r.below(3)];
        cfg.rl.lanes = 1 + r.below(4);
        cfg.rl.episodes_per_node = 4 + r.below(9);
        cfg.rl.warmup_steps = 10_000;
        match oracle {
            "pinned-inline" => {
                cfg.rl.lanes = 2 + r.below(3);
                if r.below(3) == 0 {
                    // live region: the replay buffer must cross the
                    // minibatch gate (256) so SAC updates actually fire
                    // through the pinned update stream
                    cfg.rl.lanes = 4;
                    cfg.rl.episodes_per_node = 66 + r.below(8);
                    cfg.rl.warmup_steps = 8;
                } else {
                    cfg.rl.episodes_per_node = 8 + r.below(12);
                }
            }
            "crash-resume" => {
                cfg.rl.checkpoint_every = 1 + r.below(4);
                if r.below(4) == 0 {
                    cfg.rl.lanes = 4;
                    cfg.rl.episodes_per_node = 66 + r.below(6);
                    cfg.rl.warmup_steps = 8;
                    if r.below(2) == 0 {
                        cfg.rl.learner = LearnerMode::Pinned;
                    }
                }
                let probes = 3 * cfg.rl.episodes_per_node as u64;
                cfg.rl.crash_after = 1 + r.next_u64() % probes;
            }
            _ => {}
        }

        let mut case = FuzzCase {
            oracle,
            cfg,
            batch: 2 + r.below(7),
            rounds: 1 + r.below(3),
            action_seed: (r.next_u64() & 0xFF_FFFF) | 1,
        };
        sanitize(&mut case);
        case
    }
}

/// Stream tag for the generator's RNG fork.
const FUZZ_STREAM_TAG: u64 = 0xF0_55_22;

// ---------------------------------------------------------------- oracles

/// One equivalence class: a named paired-execution check.
pub struct Oracle {
    pub name: &'static str,
    /// `true`: the two executions must agree to the bit. `false`: a
    /// relative-tolerance contract (simd-scalar only).
    pub bit_exact: bool,
    pub about: &'static str,
    run: fn(&FuzzCase) -> Result<Option<Mismatch>>,
}

/// The equivalence-class registry (DESIGN.md §14 table).
pub static ORACLES: &[Oracle] = &[
    Oracle {
        name: "serial-parallel",
        bit_exact: true,
        about: "evaluate_many(threads=1) == evaluate_many(threads=4)",
        run: oracle_serial_parallel,
    },
    Oracle {
        name: "staged-fresh",
        bit_exact: true,
        about: "one reused EvalScratch == a fresh scratch per call",
        run: oracle_staged_fresh,
    },
    Oracle {
        name: "pruned-exact",
        bit_exact: true,
        about: "evaluate_best(prune=on) argmax == the exact scan's",
        run: oracle_pruned_exact,
    },
    Oracle {
        name: "cache-nocache",
        bit_exact: true,
        about: "run_node with eval_cache=N == eval_cache=0",
        run: oracle_cache_nocache,
    },
    Oracle {
        name: "vec-serial",
        bit_exact: true,
        about: "B-lane vec-env == B serial runs (incl. replay contents)",
        run: oracle_vec_serial,
    },
    Oracle {
        name: "pinned-inline",
        bit_exact: true,
        about: "learner=pinned == learner=inline (logs, replay, updates)",
        run: oracle_pinned_inline,
    },
    Oracle {
        name: "crash-resume",
        bit_exact: true,
        about: "kill at a random probe then resume == uninterrupted",
        run: oracle_crash_resume,
    },
    Oracle {
        name: "simd-scalar",
        bit_exact: false,
        about: "SIMD kernels within relative tolerance of scalar (CLI only)",
        run: oracle_simd_scalar,
    },
];

pub fn oracle_by_name(name: &str) -> Option<&'static Oracle> {
    ORACLES.iter().find(|o| o.name == name)
}

/// Run a case against its oracle. `Ok(None)` = the contract held (or
/// the class is inapplicable here, e.g. simd-scalar without SIMD).
pub fn run_case(case: &FuzzCase) -> Result<Option<Mismatch>> {
    let o = oracle_by_name(case.oracle)
        .ok_or_else(|| Error::msg(format!("unknown oracle {}", case.oracle)))?;
    (o.run)(case)
}

// ------------------------------------------------------------ shared bits

fn fresh_agent(cfg: &RunConfig) -> Result<SacAgent> {
    let be = backend::load(&cfg.artifacts_dir, cfg.backend)?;
    SacAgent::new(be, cfg.rl, &mut Rng::new(AGENT_INIT_SEED))
}

/// The case's lane jobs: exactly `lanes` (node, seed) specs, nodes
/// cycling the configured list, per-lane seeds on the multiseed stream.
fn lane_specs(cfg: &RunConfig) -> Vec<LaneSpec> {
    let lanes = cfg.rl.lanes.max(1);
    (0..lanes)
        .map(|i| LaneSpec {
            nm: cfg.nodes_nm[i % cfg.nodes_nm.len()],
            seed: derive_seed(cfg.seed, i),
        })
        .collect()
}

fn random_action(rng: &mut Rng) -> Action {
    let mut a = Action::neutral();
    for v in a.cont.iter_mut() {
        *v = rng.uniform_in(-1.0, 1.0);
    }
    for d in a.deltas.iter_mut() {
        *d = rng.below(5) as i32 - 2;
    }
    a
}

fn mm(oracle: &'static str, artifact: Artifact, left: String, right: String) -> Mismatch {
    Mismatch { oracle, artifact, left, right }
}

fn f64s(v: f64) -> String {
    format!("{v:?} ({:#x})", v.to_bits())
}

/// Index of the reward-argmax of a scored batch (ties: first).
fn argmax(outs: &[EvalOutcome]) -> usize {
    let mut best = 0;
    for (i, o) in outs.iter().enumerate().skip(1) {
        if o.reward.total > outs[best].reward.total {
            best = i;
        }
    }
    best
}

fn diff_outcome_pair(
    oracle: &'static str,
    name: &str,
    index: usize,
    a: &EvalOutcome,
    b: &EvalOutcome,
) -> Option<Mismatch> {
    eval::diff_outcomes(a, b).map(|(field, l, r)| {
        mm(
            oracle,
            Artifact::Tensor { name: format!("{name}.{field}"), index },
            f64s(l),
            f64s(r),
        )
    })
}

/// First divergence between two `NodeResult`s: episode logs column by
/// column, then the Pareto frontier, then the summary counters.
/// `eval_stats` is deliberately excluded — cache hit/miss counters are
/// the one carve-out every bit-identity contract shares (caches restart
/// cold on resume and are absent at `eval_cache=0`).
fn diff_results(
    oracle: &'static str,
    lane: usize,
    a: &NodeResult,
    b: &NodeResult,
) -> Option<Mismatch> {
    if a.episodes.len() != b.episodes.len() {
        return Some(mm(
            oracle,
            Artifact::Scalar { name: format!("lane {lane} episode count") },
            a.episodes.len().to_string(),
            b.episodes.len().to_string(),
        ));
    }
    for (ep, (x, y)) in a.episodes.iter().zip(&b.episodes).enumerate() {
        let cols: [(&'static str, f64, f64); 8] = [
            ("reward", x.reward, y.reward),
            ("score", x.score, y.score),
            ("best_score", x.best_score, y.best_score),
            ("tokens_per_s", x.tokens_per_s, y.tokens_per_s),
            ("power_mw", x.power_mw, y.power_mw),
            ("area_mm2", x.area_mm2, y.area_mm2),
            ("eps", x.eps, y.eps),
            ("entropy", x.entropy, y.entropy),
        ];
        for (field, l, r) in cols {
            if l.to_bits() != r.to_bits() {
                return Some(mm(
                    oracle,
                    Artifact::Episode { lane, episode: ep, field },
                    f64s(l),
                    f64s(r),
                ));
            }
        }
        if x.feasible != y.feasible
            || (x.mesh_w, x.mesh_h) != (y.mesh_w, y.mesh_h)
            || x.unique_configs != y.unique_configs
        {
            let field = if x.feasible != y.feasible {
                "feasible"
            } else if (x.mesh_w, x.mesh_h) != (y.mesh_w, y.mesh_h) {
                "mesh"
            } else {
                "unique_configs"
            };
            return Some(mm(
                oracle,
                Artifact::Episode { lane, episode: ep, field },
                format!("{:?}/{}x{}/{}", x.feasible, x.mesh_w, x.mesh_h, x.unique_configs),
                format!("{:?}/{}x{}/{}", y.feasible, y.mesh_w, y.mesh_h, y.unique_configs),
            ));
        }
    }
    let (fa, fb) = (a.pareto.frontier(), b.pareto.frontier());
    if fa.len() != fb.len() {
        return Some(mm(
            oracle,
            Artifact::Scalar { name: format!("lane {lane} frontier size") },
            fa.len().to_string(),
            fb.len().to_string(),
        ));
    }
    for (i, (p, q)) in fa.iter().zip(fb).enumerate() {
        let cols: [(&'static str, f64, f64); 3] = [
            ("perf_gops", p.perf_gops, q.perf_gops),
            ("power_mw", p.power_mw, q.power_mw),
            ("area_mm2", p.area_mm2, q.area_mm2),
        ];
        for (field, l, r) in cols {
            if l.to_bits() != r.to_bits() {
                return Some(mm(
                    oracle,
                    Artifact::Frontier { lane, index: i, field },
                    f64s(l),
                    f64s(r),
                ));
            }
        }
        if p.episode != q.episode {
            return Some(mm(
                oracle,
                Artifact::Frontier { lane, index: i, field: "episode" },
                p.episode.to_string(),
                q.episode.to_string(),
            ));
        }
    }
    if a.feasible_count != b.feasible_count {
        return Some(mm(
            oracle,
            Artifact::Scalar { name: format!("lane {lane} feasible_count") },
            a.feasible_count.to_string(),
            b.feasible_count.to_string(),
        ));
    }
    let (ba, bb) = (&a.best, &b.best);
    match (ba, bb) {
        (Some(x), Some(y)) => {
            if x.episode != y.episode {
                return Some(mm(
                    oracle,
                    Artifact::Scalar { name: format!("lane {lane} best.episode") },
                    x.episode.to_string(),
                    y.episode.to_string(),
                ));
            }
            if let Some(m) =
                diff_outcome_pair(oracle, &format!("lane {lane} best"), 0, &x.outcome, &y.outcome)
            {
                return Some(m);
            }
        }
        (None, None) => {}
        _ => {
            return Some(mm(
                oracle,
                Artifact::Scalar { name: format!("lane {lane} best") },
                ba.is_some().to_string(),
                bb.is_some().to_string(),
            ));
        }
    }
    None
}

fn diff_transition(x: &Transition, y: &Transition) -> Option<(&'static str, String, String)> {
    for j in 0..SAC_STATE_DIM {
        if x.s[j].to_bits() != y.s[j].to_bits() {
            return Some(("s", format!("{:?}", x.s[j]), format!("{:?}", y.s[j])));
        }
        if x.s2[j].to_bits() != y.s2[j].to_bits() {
            return Some(("s2", format!("{:?}", x.s2[j]), format!("{:?}", y.s2[j])));
        }
    }
    for j in 0..ACT_DIM {
        if x.a_cont[j].to_bits() != y.a_cont[j].to_bits() {
            return Some((
                "a_cont",
                format!("{:?}", x.a_cont[j]),
                format!("{:?}", y.a_cont[j]),
            ));
        }
    }
    if x.a_disc != y.a_disc {
        return Some(("a_disc", format!("{:?}", x.a_disc), format!("{:?}", y.a_disc)));
    }
    if x.r.to_bits() != y.r.to_bits() {
        return Some(("r", format!("{:?}", x.r), format!("{:?}", y.r)));
    }
    if x.done.to_bits() != y.done.to_bits() {
        return Some(("done", format!("{:?}", x.done), format!("{:?}", y.done)));
    }
    for j in 0..3 {
        if x.ppa[j].to_bits() != y.ppa[j].to_bits() {
            return Some(("ppa", format!("{:?}", x.ppa[j]), format!("{:?}", y.ppa[j])));
        }
    }
    None
}

fn diff_buffers(oracle: &'static str, a: &PerBuffer, b: &PerBuffer) -> Option<Mismatch> {
    if a.len() != b.len() {
        return Some(mm(
            oracle,
            Artifact::Scalar { name: "replay length".into() },
            a.len().to_string(),
            b.len().to_string(),
        ));
    }
    for t in 0..a.len() {
        if let Some((field, l, r)) = diff_transition(a.get(t), b.get(t)) {
            return Some(mm(oracle, Artifact::Replay { slot: t, field }, l, r));
        }
    }
    None
}

// ------------------------------------------------------ evaluator oracles

/// serial↔parallel: `evaluate_many` must be order-preserving and
/// thread-count-invariant (input-position writes, DESIGN.md §3).
fn oracle_serial_parallel(case: &FuzzCase) -> Result<Option<Mismatch>> {
    let cfg = &case.cfg;
    let ev = Evaluator::new(cfg, cfg.nodes_nm[0]);
    let mut mesh = ev.initial_mesh();
    let mut rng = Rng::new(case.action_seed).fork(0xFA01);
    for round in 0..case.rounds {
        let actions: Vec<Action> =
            (0..case.batch).map(|_| random_action(&mut rng)).collect();
        let serial = ev.evaluate_many(&mesh, &actions, 1);
        let par = ev.evaluate_many(&mesh, &actions, 4);
        for (i, (s, p)) in serial.iter().zip(&par).enumerate() {
            if let Some(m) = diff_outcome_pair(
                "serial-parallel",
                &format!("round {round} outcome"),
                i,
                s,
                p,
            ) {
                return Ok(Some(m));
            }
        }
        mesh = serial[argmax(&serial)].decoded.mesh;
    }
    Ok(None)
}

/// staged↔fresh: a scratch reused across a whole action sequence must
/// leave no state behind that changes later evaluations.
fn oracle_staged_fresh(case: &FuzzCase) -> Result<Option<Mismatch>> {
    let cfg = &case.cfg;
    let ev = Evaluator::new(cfg, cfg.nodes_nm[0]);
    let mut mesh = ev.initial_mesh();
    let mut rng = Rng::new(case.action_seed).fork(0xFA02);
    let mut warm = EvalScratch::default();
    let steps = case.batch * case.rounds;
    for step in 0..steps {
        let a = random_action(&mut rng);
        let staged = ev.evaluate(&mesh, &a, &mut warm);
        let mut fresh_scratch = EvalScratch::default();
        let fresh = ev.evaluate(&mesh, &a, &mut fresh_scratch);
        if let Some(m) =
            diff_outcome_pair("staged-fresh", &format!("step {step}"), step, &fresh, &staged)
        {
            return Ok(Some(m));
        }
        if step % 3 == 2 {
            mesh = staged.decoded.mesh;
        }
    }
    Ok(None)
}

/// pruned↔exact: roofline admission pruning may skip candidates but
/// must select the identical argmax with an identical outcome.
fn oracle_pruned_exact(case: &FuzzCase) -> Result<Option<Mismatch>> {
    let cfg = &case.cfg;
    let ev = Evaluator::new(cfg, cfg.nodes_nm[0]);
    let mut mesh = ev.initial_mesh();
    let mut rng = Rng::new(case.action_seed).fork(0xFA03);
    for round in 0..case.rounds {
        let actions: Vec<Action> =
            (0..case.batch).map(|_| random_action(&mut rng)).collect();
        let exact = ev.evaluate_best(&mesh, &actions, 2, false);
        let pruned = ev.evaluate_best(&mesh, &actions, 2, true);
        if exact.best != pruned.best {
            return Ok(Some(mm(
                "pruned-exact",
                Artifact::Scalar { name: format!("round {round} argmax index") },
                exact.best.to_string(),
                pruned.best.to_string(),
            )));
        }
        let (eo, po) = (
            exact.outcomes[exact.best].as_ref().expect("exact best is scored"),
            pruned.outcomes[pruned.best].as_ref().expect("pruned best is scored"),
        );
        if let Some(m) =
            diff_outcome_pair("pruned-exact", &format!("round {round} best"), exact.best, eo, po)
        {
            return Ok(Some(m));
        }
        mesh = eo.decoded.mesh;
    }
    Ok(None)
}

/// cache↔nocache: the episode-loop memo cache is a pure memoization —
/// `run_node` results must not depend on its capacity.
fn oracle_cache_nocache(case: &FuzzCase) -> Result<Option<Mismatch>> {
    let nm = case.cfg.nodes_nm[0];
    let mut cached_cfg = case.cfg.clone();
    cached_cfg.rl.eval_cache = cached_cfg.rl.eval_cache.max(16);
    let mut plain_cfg = case.cfg.clone();
    plain_cfg.rl.eval_cache = 0;

    let run = |cfg: &RunConfig| -> Result<NodeResult> {
        let mut agent = fresh_agent(cfg)?;
        rl::run_node(cfg, nm, &mut agent, &mut Rng::new(cfg.seed))
    };
    let cached = run(&cached_cfg)?;
    let plain = run(&plain_cfg)?;
    Ok(diff_results("cache-nocache", 0, &plain, &cached))
}

// --------------------------------------------------------- engine oracles

/// B-lane↔B-serial: the vec-env stepping B (node, seed) lanes through
/// batched actor forwards must equal B independent serial runs — logs,
/// frontiers, and the interleaved replay contents (slot `t·B + lane`).
fn oracle_vec_serial(case: &FuzzCase) -> Result<Option<Mismatch>> {
    let cfg = &case.cfg;
    let specs = lane_specs(cfg);
    let b = specs.len();

    let mut vec_agent = fresh_agent(cfg)?;
    let mut update_rng = Rng::new(cfg.seed).fork(crate::rl::learner::UPDATE_STREAM_TAG);
    let vec_results = rl::run_vec(cfg, &specs, &mut vec_agent, &mut update_rng, 2)?;

    for (lane, spec) in specs.iter().enumerate() {
        let mut agent = fresh_agent(cfg)?;
        let serial = rl::run_node(cfg, spec.nm, &mut agent, &mut Rng::new(spec.seed))?;
        if let Some(m) = diff_results("vec-serial", lane, &serial, &vec_results[lane]) {
            return Ok(Some(m));
        }
        // replay interleave: vec slot t·B+lane == serial slot t
        let steps = agent.buffer.len();
        for t in 0..steps {
            let slot = t * b + lane;
            if slot >= vec_agent.buffer.len() {
                return Ok(Some(mm(
                    "vec-serial",
                    Artifact::Scalar { name: "replay length".into() },
                    (steps * b).to_string(),
                    vec_agent.buffer.len().to_string(),
                )));
            }
            if let Some((field, l, r)) =
                diff_transition(agent.buffer.get(t), vec_agent.buffer.get(slot))
            {
                return Ok(Some(mm("vec-serial", Artifact::Replay { slot, field }, l, r)));
            }
        }
    }
    Ok(None)
}

/// pinned↔inline: the pinned learner thread replays the exact inline
/// update schedule — logs, frontiers, replay, and update counts match.
fn oracle_pinned_inline(case: &FuzzCase) -> Result<Option<Mismatch>> {
    let specs = lane_specs(&case.cfg);
    let lanes = specs.len();

    let run = |mode: LearnerMode| -> Result<(Vec<NodeResult>, SacAgent)> {
        let mut cfg = case.cfg.clone();
        cfg.rl.learner = mode;
        let mut agent = fresh_agent(&cfg)?;
        let (results, _report) = rl::run_jobs_stats(&cfg, &specs, lanes, &mut agent, 2)?;
        Ok((results, agent))
    };
    let (inline_res, inline_agent) = run(LearnerMode::Inline)?;
    let (pinned_res, pinned_agent) = run(LearnerMode::Pinned)?;

    for (lane, (a, b)) in inline_res.iter().zip(&pinned_res).enumerate() {
        if let Some(m) = diff_results("pinned-inline", lane, a, b) {
            return Ok(Some(m));
        }
    }
    if let Some(m) = diff_buffers("pinned-inline", &inline_agent.buffer, &pinned_agent.buffer)
    {
        return Ok(Some(m));
    }
    if inline_agent.updates_done != pinned_agent.updates_done {
        return Ok(Some(mm(
            "pinned-inline",
            Artifact::Scalar { name: "updates_done".into() },
            inline_agent.updates_done.to_string(),
            pinned_agent.updates_done.to_string(),
        )));
    }
    Ok(None)
}

/// kill→resume↔uninterrupted: crash at the case's probe, resume from
/// the newest valid generation, and the end state must be bit-identical
/// to a run that never crashed.
fn oracle_crash_resume(case: &FuzzCase) -> Result<Option<Mismatch>> {
    let specs = lane_specs(&case.cfg);
    let lanes = specs.len();

    let run = |cfg: &RunConfig| -> Result<(Vec<NodeResult>, SacAgent)> {
        let mut agent = fresh_agent(cfg)?;
        let (results, _report) = rl::run_jobs_stats(cfg, &specs, lanes, &mut agent, 2)?;
        Ok((results, agent))
    };

    let mut ref_cfg = case.cfg.clone();
    ref_cfg.rl.checkpoint_every = 0;
    ref_cfg.rl.crash_after = 0;
    let (ref_res, ref_agent) = run(&ref_cfg)?;

    let scratch = std::env::temp_dir().join(format!(
        "silicon-rl-fuzz-{}-{:x}",
        std::process::id(),
        case.action_seed
    ));
    let _ = std::fs::remove_dir_all(&scratch);
    let mut crash_cfg = case.cfg.clone();
    crash_cfg.out_dir = scratch.to_string_lossy().into_owned();
    match run(&crash_cfg) {
        Ok(_) => {
            let _ = std::fs::remove_dir_all(&scratch);
            return Ok(Some(mm(
                "crash-resume",
                Artifact::Scalar { name: "injected crash".into() },
                format!("crash at probe {}", case.cfg.rl.crash_after),
                "run completed without crashing".into(),
            )));
        }
        Err(e) => {
            let text = format!("{e:#}");
            if !text.contains(INJECTED_CRASH_MSG) {
                let _ = std::fs::remove_dir_all(&scratch);
                return Err(e);
            }
        }
    }

    let mut res_cfg = crash_cfg.clone();
    res_cfg.rl.crash_after = 0;
    res_cfg.resume = Some(crash_cfg.out_dir.clone());
    let resumed = run(&res_cfg);
    let _ = std::fs::remove_dir_all(&scratch);
    let (res_res, res_agent) = resumed?;

    for (lane, (a, b)) in ref_res.iter().zip(&res_res).enumerate() {
        if let Some(m) = diff_results("crash-resume", lane, a, b) {
            return Ok(Some(m));
        }
    }
    if let Some(m) = diff_buffers("crash-resume", &ref_agent.buffer, &res_agent.buffer) {
        return Ok(Some(m));
    }
    if ref_agent.updates_done != res_agent.updates_done {
        return Ok(Some(mm(
            "crash-resume",
            Artifact::Scalar { name: "updates_done".into() },
            ref_agent.updates_done.to_string(),
            res_agent.updates_done.to_string(),
        )));
    }
    Ok(None)
}

// ---------------------------------------------------------- kernel oracle

/// Flip the process-global kernel path around `f`, restoring the scalar
/// reference after. ONLY the `silicon-rl fuzz` process calls this — the
/// fuzz *test* binary excludes the simd-scalar class by convention
/// (`tests/kernel_parity.rs` owns test-side flips).
fn with_kernels<T>(sel: KernelSel, f: impl FnOnce() -> T) -> T {
    kernels::set_global(sel);
    let out = f();
    kernels::set_global(KernelSel::Scalar);
    out
}

fn rel_close(a: f32, b: f32, tol: f32) -> bool {
    let denom = a.abs().max(b.abs()).max(1.0);
    (a - b).abs() <= tol * denom
}

fn diff_tensors(
    name: &str,
    scalar: &[f32],
    simd: &[f32],
    tol: f32,
) -> Option<Mismatch> {
    for (i, (s, v)) in scalar.iter().zip(simd).enumerate() {
        if !rel_close(*s, *v, tol) {
            return Some(mm(
                "simd-scalar",
                Artifact::Tensor { name: name.to_string(), index: i },
                format!("{s:?}"),
                format!("{v:?}"),
            ));
        }
    }
    None
}

/// simd↔scalar: every dispatched `nn::math` kernel at randomized shapes
/// must stay within the tolerance the parity suite contracts (matmul
/// family 1e-4, element-wise 2e-5, softmax 1e-5). Skips cleanly when
/// the CPU has no vector path.
fn oracle_simd_scalar(case: &FuzzCase) -> Result<Option<Mismatch>> {
    if kernels::detect().is_none() {
        return Ok(None);
    }
    let mut rng = Rng::new(case.action_seed).fork(0xFA04);
    let fill = |n: usize, rng: &mut Rng| -> Vec<f32> {
        (0..n)
            .map(|_| {
                // ~1/8 exact zeros: exercises the kernels' masked tails
                if rng.below(8) == 0 {
                    0.0
                } else {
                    rng.uniform_in(-2.0, 2.0) as f32
                }
            })
            .collect()
    };
    for round in 0..case.rounds.max(2) {
        let m = 1 + rng.below(6);
        let k = 1 + rng.below(96);
        let n = 1 + rng.below(96);
        let x = fill(m * k, &mut rng);
        let w = fill(k * n, &mut rng);
        let b = fill(n, &mut rng);
        let g = fill(m * n, &mut rng);

        // forward matmul + bias
        let mut y_s = vec![0.0f32; m * n];
        let mut y_v = y_s.clone();
        with_kernels(KernelSel::Scalar, || math::matmul_bias(&x, &w, &b, &mut y_s, m, k, n));
        with_kernels(KernelSel::Simd, || math::matmul_bias(&x, &w, &b, &mut y_v, m, k, n));
        if let Some(mis) =
            diff_tensors(&format!("round {round} matmul_bias.y"), &y_s, &y_v, 1e-4)
        {
            return Ok(Some(mis));
        }

        // backward data
        let mut dx_s = vec![0.0f32; m * k];
        let mut dx_v = dx_s.clone();
        with_kernels(KernelSel::Scalar, || math::matmul_wt(&g, &w, &mut dx_s, m, k, n));
        with_kernels(KernelSel::Simd, || math::matmul_wt(&g, &w, &mut dx_v, m, k, n));
        if let Some(mis) =
            diff_tensors(&format!("round {round} matmul_wt.dx"), &dx_s, &dx_v, 1e-4)
        {
            return Ok(Some(mis));
        }

        // backward weights + bias
        let (mut dw_s, mut db_s) = (vec![0.0f32; k * n], vec![0.0f32; n]);
        let (mut dw_v, mut db_v) = (dw_s.clone(), db_s.clone());
        with_kernels(KernelSel::Scalar, || {
            math::grad_w_b(&x, &g, &mut dw_s, &mut db_s, m, k, n)
        });
        with_kernels(KernelSel::Simd, || {
            math::grad_w_b(&x, &g, &mut dw_v, &mut db_v, m, k, n)
        });
        if let Some(mis) =
            diff_tensors(&format!("round {round} grad_w_b.dw"), &dw_s, &dw_v, 1e-4)
        {
            return Ok(Some(mis));
        }
        if let Some(mis) =
            diff_tensors(&format!("round {round} grad_w_b.db"), &db_s, &db_v, 1e-4)
        {
            return Ok(Some(mis));
        }

        // element-wise GELU forward/backward
        let z = fill(m * n, &mut rng);
        let mut h_s = vec![0.0f32; m * n];
        let mut h_v = h_s.clone();
        with_kernels(KernelSel::Scalar, || math::gelu_map(&z, &mut h_s));
        with_kernels(KernelSel::Simd, || math::gelu_map(&z, &mut h_v));
        if let Some(mis) =
            diff_tensors(&format!("round {round} gelu_map.h"), &h_s, &h_v, 2e-5)
        {
            return Ok(Some(mis));
        }
        let mut gb_s = g.clone();
        let mut gb_v = g.clone();
        with_kernels(KernelSel::Scalar, || math::gelu_bwd_inplace(&mut gb_s, &z));
        with_kernels(KernelSel::Simd, || math::gelu_bwd_inplace(&mut gb_v, &z));
        if let Some(mis) =
            diff_tensors(&format!("round {round} gelu_bwd.g"), &gb_s, &gb_v, 2e-5)
        {
            return Ok(Some(mis));
        }

        // row softmax
        let mut sm_s = fill(m * n, &mut rng);
        let mut sm_v = sm_s.clone();
        with_kernels(KernelSel::Scalar, || math::softmax_rows(&mut sm_s, n));
        with_kernels(KernelSel::Simd, || math::softmax_rows(&mut sm_v, n));
        if let Some(mis) =
            diff_tensors(&format!("round {round} softmax.z"), &sm_s, &sm_v, 1e-5)
        {
            return Ok(Some(mis));
        }

        // fused Adam step
        let step = math::AdamStep::new(3e-4, 0.9, 0.999, 1e-8, round as f64);
        let len = m * n;
        let (p0, m0, v0) = (fill(len, &mut rng), fill(len, &mut rng), fill(len, &mut rng));
        let v0: Vec<f32> = v0.iter().map(|v| v.abs()).collect();
        let (mut p_s, mut m_s, mut v_s) = (p0.clone(), m0.clone(), v0.clone());
        let (mut p_v, mut m_v, mut v_v) = (p0, m0, v0);
        with_kernels(KernelSel::Scalar, || step.apply(&mut p_s, &g, &mut m_s, &mut v_s));
        with_kernels(KernelSel::Simd, || step.apply(&mut p_v, &g, &mut m_v, &mut v_v));
        if let Some(mis) =
            diff_tensors(&format!("round {round} adam.p"), &p_s, &p_v, 1e-4)
        {
            return Ok(Some(mis));
        }
    }
    Ok(None)
}

// ---------------------------------------------------------------- shrinker

/// Result of a shrink run: the minimal still-failing case, the mismatch
/// it produces, and the oracle-execution budget spent.
#[derive(Debug)]
pub struct ShrinkOutcome {
    pub case: FuzzCase,
    pub mismatch: Mismatch,
    /// Oracle executions performed (including the initial confirmation).
    pub attempts: usize,
    /// Accepted shrink steps.
    pub accepted: usize,
}

/// Per-axis delta-debugging proposals: each returned case is one
/// mutation of `case` toward a smaller/more-default configuration,
/// sanitized, and distinct from `case` itself. Ordered so the biggest
/// cost reductions (episodes, lanes, rounds/batch) are tried first.
fn proposals(case: &FuzzCase) -> Vec<FuzzCase> {
    let mut out: Vec<FuzzCase> = Vec::new();
    let fp = case.fingerprint();
    let mut add = |mutate: &dyn Fn(&mut FuzzCase)| {
        let mut c = case.clone();
        mutate(&mut c);
        sanitize(&mut c);
        if c.fingerprint() != fp && !out.iter().any(|p| p.fingerprint() == c.fingerprint()) {
            out.push(c);
        }
    };

    let e = case.cfg.rl.episodes_per_node;
    if e > 1 {
        add(&|c| c.cfg.rl.episodes_per_node = e / 2);
        add(&|c| c.cfg.rl.episodes_per_node = e - 1);
    }
    let l = case.cfg.rl.lanes;
    if l > 1 {
        add(&|c| c.cfg.rl.lanes = l / 2);
        add(&|c| c.cfg.rl.lanes = l - 1);
    }
    if case.rounds > 1 {
        add(&|c| c.rounds = 1);
    }
    let bt = case.batch;
    if bt > 1 {
        add(&|c| c.batch = bt / 2);
        add(&|c| c.batch = bt - 1);
    }
    if case.cfg.nodes_nm.len() > 1 {
        add(&|c| c.cfg.nodes_nm.truncate(1));
    }
    if case.cfg.nodes_nm != [7] {
        add(&|c| c.cfg.nodes_nm = vec![7]);
    }
    if case.cfg.seq_len.is_some() {
        add(&|c| c.cfg.seq_len = None);
    }
    if case.cfg.batch.is_some() {
        add(&|c| c.cfg.batch = None);
    }
    // smallest registered graph — the cheapest still-failing workload
    if case.cfg.workload.name() != "smolvlm" {
        add(&|c| {
            c.cfg.workload = Workload::parse("smolvlm").expect("smolvlm is registered")
        });
    }
    if case.cfg.mode.name == "low-power" {
        add(&|c| c.cfg.mode = ModeConfig::high_performance());
    }
    if !matches!(case.cfg.kv_strategy, KvStrategy::Full) {
        add(&|c| c.cfg.kv_strategy = KvStrategy::Full);
    }
    if case.cfg.rl.prune {
        add(&|c| c.cfg.rl.prune = false);
    }
    if case.cfg.rl.eval_cache != 256 {
        add(&|c| c.cfg.rl.eval_cache = 256);
    }
    if case.cfg.rl.warmup_steps < 10_000 {
        add(&|c| c.cfg.rl.warmup_steps = 10_000);
    }
    if !matches!(case.cfg.rl.learner, LearnerMode::Inline) {
        add(&|c| c.cfg.rl.learner = LearnerMode::Inline);
    }
    let ck = case.cfg.rl.checkpoint_every;
    if ck > 1 {
        add(&|c| c.cfg.rl.checkpoint_every = ck / 2);
    }
    let cr = case.cfg.rl.crash_after;
    if cr > 1 {
        add(&|c| c.cfg.rl.crash_after = cr / 2);
    }
    out
}

/// Delta-debug `case` against an arbitrary checker (the real oracle in
/// production, an intentionally-broken one in the mutation-smoke test).
/// Returns `None` when the starting case doesn't fail. A proposal whose
/// check errors is treated as rejected — the confirmed failing case is
/// never abandoned for an unrunnable mutation.
pub fn shrink_with(
    case: &FuzzCase,
    check: &dyn Fn(&FuzzCase) -> Result<Option<Mismatch>>,
    budget: usize,
) -> Result<Option<ShrinkOutcome>> {
    let mut attempts = 1usize;
    let Some(mut mismatch) = check(case)? else {
        return Ok(None);
    };
    let mut cur = case.clone();
    let mut accepted = 0usize;
    'outer: while attempts < budget {
        let mut improved = false;
        for p in proposals(&cur) {
            if attempts >= budget {
                break 'outer;
            }
            attempts += 1;
            if let Ok(Some(m)) = check(&p) {
                cur = p;
                mismatch = m;
                accepted += 1;
                improved = true;
                break;
            }
        }
        if !improved {
            break;
        }
    }
    Ok(Some(ShrinkOutcome { case: cur, mismatch, attempts, accepted }))
}

/// Shrink against the case's own registered oracle.
pub fn shrink(case: &FuzzCase, budget: usize) -> Result<Option<ShrinkOutcome>> {
    shrink_with(case, &run_case, budget)
}

// ------------------------------------------------------------------ tests

#[cfg(test)]
mod tests {
    use super::*;

    fn gen_cases(seed: u64, n: usize) -> Vec<FuzzCase> {
        let classes = class_names();
        let mut g = CaseGen::new(seed, &classes).unwrap();
        (0..n).map(|_| g.next_case()).collect()
    }

    #[test]
    fn generator_is_seed_stable() {
        let a = gen_cases(42, 12);
        let b = gen_cases(42, 12);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.fingerprint(), y.fingerprint());
        }
        let c = gen_cases(43, 12);
        assert!(
            a.iter().zip(&c).any(|(x, y)| x.fingerprint() != y.fingerprint()),
            "different seeds produced identical case streams"
        );
    }

    #[test]
    fn repro_round_trips_for_every_class() {
        for case in gen_cases(7, 40) {
            let text = case.to_repro();
            let back = FuzzCase::from_repro(&text).unwrap();
            assert_eq!(
                back.fingerprint(),
                case.fingerprint(),
                "repro drift for class {}:\n{text}",
                case.oracle
            );
            assert!(case.cmd_line().starts_with("silicon-rl fuzz oracle="));
        }
    }

    #[test]
    fn sanitize_is_idempotent_and_enforces_class_envelopes() {
        for mut case in gen_cases(11, 40) {
            let once = case.fingerprint();
            sanitize(&mut case);
            assert_eq!(case.fingerprint(), once, "sanitize not idempotent");
            match case.oracle {
                "vec-serial" => {
                    assert!(case.cfg.rl.lanes >= 2);
                    assert_eq!(case.cfg.rl.warmup_steps, 10_000);
                    assert_eq!(case.cfg.rl.checkpoint_every, 0);
                }
                "crash-resume" => {
                    assert!(case.cfg.rl.checkpoint_every >= 1);
                    let probes = 3 * case.cfg.rl.episodes_per_node as u64;
                    assert!((1..=probes).contains(&case.cfg.rl.crash_after));
                    assert!(!matches!(case.cfg.rl.learner, LearnerMode::Async));
                }
                "pruned-exact" => assert!(case.batch >= 2),
                _ => {}
            }
        }
    }

    #[test]
    fn shrinker_reaches_axis_minima_against_broken_checker() {
        let classes = class_names();
        let mut g = CaseGen::new(3, &classes).unwrap();
        let mut case = g.next_case();
        case.oracle = "vec-serial";
        case.cfg.rl.episodes_per_node = 24;
        case.cfg.rl.lanes = 4;
        case.batch = 9;
        sanitize(&mut case);

        let fake = |c: &FuzzCase| -> Result<Option<Mismatch>> {
            Ok((c.cfg.rl.episodes_per_node >= 3 && c.cfg.rl.lanes >= 2).then(|| {
                mm(
                    "vec-serial",
                    Artifact::Scalar { name: "synthetic".into() },
                    "a".into(),
                    "b".into(),
                )
            }))
        };
        let out = shrink_with(&case, &fake, 10_000).unwrap().expect("case must fail");
        assert_eq!(out.case.cfg.rl.episodes_per_node, 3, "episodes not minimal");
        assert_eq!(out.case.cfg.rl.lanes, 2, "lanes not minimal");
        assert_eq!(out.case.batch, 1, "batch not minimal");
        assert!(out.accepted > 0);
        // the shrunk config still fails the (broken) oracle
        assert!(fake(&out.case).unwrap().is_some());
    }

    #[test]
    fn passing_case_is_not_shrunk() {
        let classes = class_names();
        let mut g = CaseGen::new(5, &classes).unwrap();
        let case = g.next_case();
        let pass = |_: &FuzzCase| -> Result<Option<Mismatch>> { Ok(None) };
        assert!(shrink_with(&case, &pass, 100).unwrap().is_none());
    }
}
