//! The RL optimizer (§3.11–§3.16, Algorithm 1): SAC driver over the
//! AOT-compiled networks, prioritized replay, adaptive ε-greedy
//! exploration, world-model MPC planning, the Pareto archive, and the
//! random/grid search baselines of §4.14.

pub mod agent;
pub mod baselines;
pub mod explore;
pub mod loop_;
pub mod multiseed;
pub mod pareto;
pub mod per;

pub use agent::{SacAgent, UpdateMetrics};
pub use explore::EpsSchedule;
pub use loop_::{run_node, BestConfig, EpisodeLog, NodeResult};
pub use multiseed::{run_seeds, run_seeds_t, seeds_table, MultiSeedResult, SeedStat};
pub use pareto::{ParetoArchive, ParetoPoint};
pub use per::{PerBuffer, Transition};
