//! The RL optimizer (§3.11–§3.16, Algorithm 1): SAC driver over the
//! AOT-compiled networks, prioritized replay, adaptive ε-greedy
//! exploration, world-model MPC planning, the Pareto archive, the
//! random/grid search baselines of §4.14, the vectorized multi-env
//! rollout engine ([`vecenv`]) that steps (node, seed) lanes in lockstep
//! through batched actor forwards (DESIGN.md §9), and the async
//! actor-learner engine ([`learner`]) that moves the update schedule
//! onto a dedicated thread behind versioned parameter snapshots
//! (DESIGN.md §11), the crash-safe checkpoint/resume subsystem
//! ([`checkpoint`]) with its fault-injection harness (DESIGN.md §13),
//! and the randomized equivalence fuzz harness ([`fuzz`]) that checks
//! the stack's bit-identity contracts at arbitrary points of the
//! config space with counterexample shrinking (DESIGN.md §14).

pub mod agent;
pub mod atlas;
pub mod baselines;
pub mod checkpoint;
pub mod explore;
pub mod fuzz;
pub mod learner;
pub mod loop_;
pub mod multiseed;
pub mod pareto;
pub mod per;
pub mod vecenv;

pub use agent::{LaneDecision, SacAgent, UpdateMetrics};
pub use atlas::{AtlasCounters, AtlasPoint, AtlasResult, PointStatus, PruneKind};
pub use explore::EpsSchedule;
pub use fuzz::{CaseGen, FuzzCase, Mismatch, ShrinkOutcome};
pub use learner::{LearnerMode, LearnerReport};
pub use loop_::{run_node, BestConfig, EpisodeLog, NodeResult};
pub use multiseed::{run_seeds, run_seeds_t, seeds_table, MultiSeedResult, SeedStat};
pub use pareto::{ParetoArchive, ParetoPoint};
pub use per::{PerBuffer, Transition};
pub use vecenv::{run_jobs, run_jobs_stats, run_jobs_stats_shared, run_vec, LaneSpec};
