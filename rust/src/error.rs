//! Crate-local error type replacing the `anyhow` dependency so the crate
//! builds offline with zero external dependencies (the only path
//! dependency is the vendored `xla` bindings).
//!
//! API mirrors the subset of anyhow the crate used: a message-carrying
//! [`Error`], a [`Result`] alias with a defaulted error parameter, a
//! [`Context`] extension trait for `Result`/`Option`, and the [`bail!`]
//! macro.

use std::fmt;

/// A message-carrying error. Context wraps outer-to-inner, rendered as
/// `outer: inner` so `{e}` and `{e:#}` both read as a cause chain.
#[derive(Debug, Clone)]
pub struct Error {
    msg: String,
}

impl Error {
    /// Build an error from anything displayable (anyhow::Error::msg).
    pub fn msg(m: impl fmt::Display) -> Error {
        Error { msg: m.to_string() }
    }

    fn wrap(ctx: impl fmt::Display, cause: impl fmt::Display) -> Error {
        Error { msg: format!("{ctx}: {cause}") }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.msg)
    }
}

impl std::error::Error for Error {}

impl From<String> for Error {
    fn from(msg: String) -> Error {
        Error { msg }
    }
}

impl From<&str> for Error {
    fn from(msg: &str) -> Error {
        Error { msg: msg.to_string() }
    }
}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Error {
        Error::msg(e)
    }
}

impl From<std::num::ParseIntError> for Error {
    fn from(e: std::num::ParseIntError) -> Error {
        Error::msg(e)
    }
}

impl From<std::num::ParseFloatError> for Error {
    fn from(e: std::num::ParseFloatError) -> Error {
        Error::msg(e)
    }
}

impl From<xla::Error> for Error {
    fn from(e: xla::Error) -> Error {
        Error::msg(e)
    }
}

/// Result alias with a defaulted error parameter (anyhow::Result).
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Attach context to errors (anyhow::Context).
pub trait Context<T> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: fmt::Display> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T> {
        self.map_err(|e| Error::wrap(ctx, e))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| Error::wrap(f(), e))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(ctx))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Early-return with a formatted [`Error`] (anyhow::bail!).
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::error::Error::msg(format!($($arg)*)))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fails() -> Result<()> {
        bail!("broken {}", 42)
    }

    #[test]
    fn bail_formats() {
        let e = fails().unwrap_err();
        assert_eq!(e.to_string(), "broken 42");
    }

    #[test]
    fn context_chains_outer_to_inner() {
        let r: std::result::Result<(), String> = Err("inner".into());
        let e = r.context("outer").unwrap_err();
        assert_eq!(e.to_string(), "outer: inner");
        let o: Option<u32> = None;
        assert_eq!(o.context("missing").unwrap_err().to_string(), "missing");
        let some: Option<u32> = Some(7);
        assert_eq!(some.with_context(|| "unused").unwrap(), 7);
    }

    #[test]
    fn io_and_parse_errors_convert() {
        fn io() -> Result<String> {
            Ok(std::fs::read_to_string("/definitely/not/a/real/path")?)
        }
        assert!(io().is_err());
        fn parse() -> Result<usize> {
            Ok("xyz".parse::<usize>()?)
        }
        assert!(parse().is_err());
    }
}
