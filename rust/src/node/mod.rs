//! Process-node characterization (§3.15 "foundry-calibrated process node
//! table").
//!
//! The paper interpolates power/area/energy factors from a proprietary
//! foundry table. Per DESIGN.md §4 we substitute a table *inverted from the
//! paper's own reported per-node results* (Tables 10–12), so the RL agent
//! explores the same PPA landscape the paper reports and the scaling
//! exponents of Table 13 emerge from the same data.

pub mod table;

pub use table::{NodeSpec, NodeTable, PAPER_NODES_NM};

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_seven_paper_nodes_present() {
        let t = NodeTable::paper();
        assert_eq!(t.nodes().len(), 7);
        for nm in PAPER_NODES_NM {
            assert!(t.get(nm).is_some(), "missing {nm}nm");
        }
    }

    #[test]
    fn fmax_matches_paper_clock_pins() {
        // §3.15: "1 GHz at 3nm, 820 MHz at 5nm, 250 MHz at 28nm"
        let t = NodeTable::paper();
        assert_eq!(t.get(3).unwrap().fmax_mhz, 1000.0);
        assert_eq!(t.get(5).unwrap().fmax_mhz, 820.0);
        assert_eq!(t.get(28).unwrap().fmax_mhz, 250.0);
    }

    #[test]
    fn monotonic_scaling_directions() {
        let t = NodeTable::paper();
        let nodes = t.nodes();
        for w in nodes.windows(2) {
            // larger (older) nodes: lower fmax, higher MAC energy,
            // higher logic area scale, higher per-hop energy
            assert!(w[0].fmax_mhz >= w[1].fmax_mhz);
            assert!(w[0].mac_energy_pj <= w[1].mac_energy_pj);
            assert!(w[0].area_scale <= w[1].area_scale);
            assert!(w[0].noc_hop_pj_per_bit <= w[1].noc_hop_pj_per_bit);
        }
    }

    #[test]
    fn leakage_worse_at_advanced_nodes() {
        // §4.12: leakage dominates at advanced nodes (97% at 3nm vs 51% at
        // 28nm for SmolVLM) — per-MB SRAM leakage must be higher at <=14nm
        // than at 22/28nm.
        let t = NodeTable::paper();
        assert!(
            t.get(3).unwrap().sram_leak_mw_per_mb > t.get(28).unwrap().sram_leak_mw_per_mb
        );
    }

    #[test]
    fn interpolation_between_nodes() {
        let t = NodeTable::paper();
        let s = t.interpolated(6.0);
        let n5 = t.get(5).unwrap();
        let n7 = t.get(7).unwrap();
        assert!(s.mac_energy_pj > n5.mac_energy_pj);
        assert!(s.mac_energy_pj < n7.mac_energy_pj);
    }

    #[test]
    fn kappa_p_relative_to_28nm_is_below_one_for_advanced() {
        let t = NodeTable::paper();
        // Eq 62: kappa_P(n) = sqrt(A_scale) * Vdd^2 relative to 28nm
        assert!(t.get(3).unwrap().kappa_p() < t.get(28).unwrap().kappa_p());
    }
}
