//! The calibrated node table.
//!
//! Every constant below is inverted from the paper's reported results
//! (DESIGN.md §6 shows the derivations):
//! * `fmax_mhz` — §3.15's clock pins (1 GHz @3nm … 250 MHz @28nm).
//! * `mac_energy_pj` — Table 12 compute power / (cores·lanes·f):
//!   0.166 pJ/FP16-MAC at 3nm rising to 0.91 pJ at 28nm.
//! * `sram_dyn_mw_per_core_ghz` — Table 12 SRAM column per core-GHz.
//! * `rom_read_mw_per_mb_at_fmax` — Table 12 ROM-read column / 14,960 MB.
//! * `noc_hop_pj_per_bit` — Table 12 NoC column / (traffic · mean hops).
//! * `sram_leak_mw_per_mb` — Table 12 leakage / total SRAM MB; highest at
//!   advanced nodes (the §4.12 leakage-vs-density trade-off).
//! * `area_scale` — Table 10 area column solved against logic+ROM+SRAM.



use crate::util::lerp;

/// The 7 process nodes evaluated in the paper (§4.1).
pub const PAPER_NODES_NM: [u32; 7] = [3, 5, 7, 10, 14, 22, 28];

/// Electrical/physical characterization of one process node.
#[derive(Debug, Clone, PartialEq)]
pub struct NodeSpec {
    /// Feature size in nm.
    pub nm: u32,
    /// Maximum achievable clock (MHz); the RL pins to this in
    /// high-performance mode (§3.15).
    pub fmax_mhz: f64,
    /// Nominal supply voltage (V).
    pub vdd: f64,
    /// Energy per FP16 multiply-accumulate (pJ).
    pub mac_energy_pj: f64,
    /// SRAM dynamic read/write power per core per GHz of clock (mW).
    pub sram_dyn_mw_per_core_ghz: f64,
    /// Weight-ROM read power per MB of model weights at fmax (mW/MB);
    /// scales linearly with f/fmax (Eq 62's W_total·E_dyn·α term).
    pub rom_read_mw_per_mb_at_fmax: f64,
    /// NoC wire+router energy per bit per mesh hop (pJ).
    pub noc_hop_pj_per_bit: f64,
    /// SRAM peripheral leakage per MB (mW). ROM has sleep transistors on
    /// the Vdd rail (§3.15) and does not leak.
    pub sram_leak_mw_per_mb: f64,
    /// Logic/memory area scale factor relative to 3nm (=1.0 at 3nm).
    pub area_scale: f64,
    /// Fixed per-core logic area at 3nm density (mm²): scalar pipeline,
    /// fetch, reservation stations.
    pub core_base_mm2: f64,
    /// Incremental logic area per FP16 vector lane at 3nm density (mm²).
    pub lane_mm2: f64,
    /// Weight-ROM density at 3nm (mm²/MB), scaled by `area_scale`.
    pub rom_mm2_per_mb: f64,
    /// SRAM density at 3nm (mm²/MB), scaled by `area_scale`.
    pub sram_mm2_per_mb: f64,
}

impl NodeSpec {
    /// Eq 62's node power-scaling factor κ_P(n) = √A_scale(n) · V_dd²(n),
    /// normalized so κ_P(28nm) = 1 in `NodeTable::paper()`.
    pub fn kappa_p(&self) -> f64 {
        (self.area_scale / 10.88).sqrt() * (self.vdd / 0.90) * (self.vdd / 0.90)
    }

    /// Logic area of one core with `lanes` FP16 vector lanes (mm²).
    pub fn core_logic_mm2(&self, lanes: f64) -> f64 {
        (self.core_base_mm2 + self.lane_mm2 * lanes) * self.area_scale
    }

    /// ROM area for `mb` megabytes of weights (mm²).
    pub fn rom_mm2(&self, mb: f64) -> f64 {
        self.rom_mm2_per_mb * self.area_scale * mb
    }

    /// SRAM area for `mb` megabytes (mm²).
    pub fn sram_mm2(&self, mb: f64) -> f64 {
        self.sram_mm2_per_mb * self.area_scale * mb
    }
}

/// Ordered collection of node specs (ascending nm) with interpolation.
#[derive(Debug, Clone)]
pub struct NodeTable {
    nodes: Vec<NodeSpec>,
}

impl NodeTable {
    /// The paper-calibrated 7-node table.
    pub fn paper() -> Self {
        let mk = |nm: u32,
                  fmax: f64,
                  vdd: f64,
                  mac: f64,
                  sram_dyn: f64,
                  rom_rd: f64,
                  hop: f64,
                  leak: f64,
                  ascale: f64| NodeSpec {
            nm,
            fmax_mhz: fmax,
            vdd,
            mac_energy_pj: mac,
            sram_dyn_mw_per_core_ghz: sram_dyn,
            rom_read_mw_per_mb_at_fmax: rom_rd,
            noc_hop_pj_per_bit: hop,
            sram_leak_mw_per_mb: leak,
            area_scale: ascale,
            core_base_mm2: 0.050,
            lane_mm2: 0.00153,
            rom_mm2_per_mb: 0.020,
            sram_mm2_per_mb: 0.080,
        };
        NodeTable {
            nodes: vec![
                //  nm  fmax  vdd   mac    sramd  rom_rd   hop    leak  area
                mk(3, 1000.0, 0.55, 0.166, 0.770, 0.1860, 0.119, 22.3, 1.00),
                mk(5, 820.0, 0.60, 0.256, 1.154, 0.1760, 0.208, 30.4, 1.53),
                mk(7, 570.0, 0.65, 0.408, 1.842, 0.1280, 0.450, 28.3, 2.32),
                mk(10, 520.0, 0.70, 0.425, 1.989, 0.0935, 0.532, 24.9, 3.56),
                mk(14, 400.0, 0.75, 0.527, 2.527, 0.0469, 0.660, 19.5, 5.07),
                mk(22, 250.0, 0.85, 0.863, 4.313, 0.0148, 1.080, 8.1, 8.21),
                mk(28, 250.0, 0.90, 0.910, 5.390, 0.0088, 1.100, 7.3, 10.88),
            ],
        }
    }

    pub fn nodes(&self) -> &[NodeSpec] {
        &self.nodes
    }

    pub fn get(&self, nm: u32) -> Option<&NodeSpec> {
        self.nodes.iter().find(|n| n.nm == nm)
    }

    /// Linear interpolation between bracketing nodes for off-table sizes
    /// (the paper's surrogate heads "interpolate from the process node
    /// table").
    pub fn interpolated(&self, nm: f64) -> NodeSpec {
        let first = self.nodes.first().expect("empty node table");
        let last = self.nodes.last().expect("empty node table");
        if nm <= first.nm as f64 {
            return first.clone();
        }
        if nm >= last.nm as f64 {
            return last.clone();
        }
        let hi_idx = self
            .nodes
            .iter()
            .position(|n| n.nm as f64 >= nm)
            .expect("bracketing node");
        let (lo, hi) = (&self.nodes[hi_idx - 1], &self.nodes[hi_idx]);
        let (a, b) = (lo.nm as f64, hi.nm as f64);
        let f = |x: f64, y: f64| lerp(nm, a, b, x, y);
        NodeSpec {
            nm: nm.round() as u32,
            fmax_mhz: f(lo.fmax_mhz, hi.fmax_mhz),
            vdd: f(lo.vdd, hi.vdd),
            mac_energy_pj: f(lo.mac_energy_pj, hi.mac_energy_pj),
            sram_dyn_mw_per_core_ghz: f(
                lo.sram_dyn_mw_per_core_ghz,
                hi.sram_dyn_mw_per_core_ghz,
            ),
            rom_read_mw_per_mb_at_fmax: f(
                lo.rom_read_mw_per_mb_at_fmax,
                hi.rom_read_mw_per_mb_at_fmax,
            ),
            noc_hop_pj_per_bit: f(lo.noc_hop_pj_per_bit, hi.noc_hop_pj_per_bit),
            sram_leak_mw_per_mb: f(lo.sram_leak_mw_per_mb, hi.sram_leak_mw_per_mb),
            area_scale: f(lo.area_scale, hi.area_scale),
            core_base_mm2: f(lo.core_base_mm2, hi.core_base_mm2),
            lane_mm2: f(lo.lane_mm2, hi.lane_mm2),
            rom_mm2_per_mb: f(lo.rom_mm2_per_mb, hi.rom_mm2_per_mb),
            sram_mm2_per_mb: f(lo.sram_mm2_per_mb, hi.sram_mm2_per_mb),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_table_is_sorted_ascending() {
        let t = NodeTable::paper();
        for w in t.nodes().windows(2) {
            assert!(w[0].nm < w[1].nm);
        }
    }

    #[test]
    fn interpolation_clamps_at_extremes() {
        let t = NodeTable::paper();
        assert_eq!(t.interpolated(1.0), *t.get(3).unwrap());
        assert_eq!(t.interpolated(40.0), *t.get(28).unwrap());
    }

    #[test]
    fn rom_area_at_3nm_matches_design_md_fit() {
        // 14,960 MB of weight ROM ≈ 299 mm² at 3nm (DESIGN.md §6)
        let t = NodeTable::paper();
        let rom = t.get(3).unwrap().rom_mm2(14960.0);
        assert!((rom - 299.2).abs() < 1.0, "rom {rom}");
    }

    #[test]
    fn core_logic_at_3nm_with_96_lanes_about_0p2_mm2() {
        let t = NodeTable::paper();
        let a = t.get(3).unwrap().core_logic_mm2(96.0);
        assert!((a - 0.197).abs() < 0.005, "core {a}");
    }
}
