//! Memory hierarchy model (§3.6): WMEM capacity constraint (Eq 14), DMEM
//! partitioning (Eq 15), effective bandwidth (Eq 16), and the tile-level
//! memory-pressure score (Eq 17) that enters the state vector.

use crate::arch::TileConfig;

/// λ_d of Eq 17: data-memory pressure weight relative to weight memory.
pub const LAMBDA_D: f64 = 0.5;

/// DMEM split into input/output/scratch buffers (Eq 15). Fractions are
/// RL-controlled (Memory/Load Partition action group) and sum to ≤ 1.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct DmemSplit {
    pub input_frac: f64,
    pub output_frac: f64,
}

impl DmemSplit {
    pub fn new(input_frac: f64, output_frac: f64) -> Self {
        // guarantee a minimum scratch allocation (Eq 28)
        let input_frac = input_frac.clamp(0.05, 0.85);
        let output_frac = output_frac.clamp(0.05, 0.9 - input_frac);
        DmemSplit { input_frac, output_frac }
    }

    pub fn scratch_frac(&self) -> f64 {
        1.0 - self.input_frac - self.output_frac
    }

    /// Byte capacities (input, output, scratch) for a tile's DMEM.
    pub fn capacities(&self, dmem_bytes: f64) -> (f64, f64, f64) {
        (
            dmem_bytes * self.input_frac,
            dmem_bytes * self.output_frac,
            dmem_bytes * self.scratch_frac(),
        )
    }
}

/// Eq 14: Σ WMEM_i ≥ W_total — can the mesh hold the model at all?
pub fn wmem_feasible(tiles: &[TileConfig], total_weight_bytes: f64) -> bool {
    let cap: f64 = tiles.iter().map(|t| t.wmem_kb as f64 * 1024.0).sum();
    cap >= total_weight_bytes
}

/// Total WMEM overflow in bytes (0 when feasible) — drives P_mem (Eq 40).
pub fn wmem_overflow_bytes(tiles: &[TileConfig], used_per_tile: &[f64]) -> f64 {
    tiles
        .iter()
        .zip(used_per_tile)
        .map(|(t, &used)| (used - t.wmem_kb as f64 * 1024.0).max(0.0))
        .sum()
}

/// Eq 16: BW_eff = min(BW_pk, V / (C · T_clk)).
/// `volume_bytes` over `cycles` at `clock_mhz` against peak `bw_pk_bytes`.
pub fn effective_bandwidth(
    bw_pk_bytes: f64,
    volume_bytes: f64,
    cycles: f64,
    clock_mhz: f64,
) -> f64 {
    if cycles <= 0.0 {
        return bw_pk_bytes;
    }
    let t_clk = 1.0 / (clock_mhz * 1e6);
    bw_pk_bytes.min(volume_bytes / (cycles * t_clk))
}

/// Eq 17: P_i = W_used/W_alloc + λ_d · D_used/D_alloc.
pub fn pressure(w_used: f64, w_alloc: f64, d_used: f64, d_alloc: f64) -> f64 {
    let w = if w_alloc > 0.0 { w_used / w_alloc } else { 0.0 };
    let d = if d_alloc > 0.0 { d_used / d_alloc } else { 0.0 };
    w + LAMBDA_D * d
}

/// Peak per-tile SRAM bandwidth (bytes/s): `ports` concurrent accesses of
/// VLEN bits per cycle.
pub fn tile_peak_bw(vlen_bits: u32, ports: u32, clock_mhz: f64) -> f64 {
    (vlen_bits as f64 / 8.0) * ports as f64 * clock_mhz * 1e6
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::TileConfig;

    fn tile(wmem_kb: u32) -> TileConfig {
        TileConfig {
            tile: 0,
            x: 0,
            y: 0,
            fetch: 4,
            vlen_bits: 1024,
            stanum: 4,
            dmem_kb: 64,
            wmem_kb,
            imem_kb: 8,
        }
    }

    #[test]
    fn dmem_split_preserves_scratch() {
        let s = DmemSplit::new(0.9, 0.9);
        assert!(s.scratch_frac() >= 0.1 - 1e-12);
        let (i, o, sc) = s.capacities(1024.0);
        assert!((i + o + sc - 1024.0).abs() < 1e-9);
    }

    #[test]
    fn wmem_feasibility_eq14() {
        let tiles: Vec<_> = (0..4).map(|_| tile(1024)).collect(); // 4 MB total
        assert!(wmem_feasible(&tiles, 3.0 * 1024.0 * 1024.0));
        assert!(!wmem_feasible(&tiles, 5.0 * 1024.0 * 1024.0));
    }

    #[test]
    fn overflow_accumulates_only_deficits() {
        let tiles = vec![tile(1), tile(1)]; // 1 KB each
        let used = vec![2048.0, 512.0];
        assert_eq!(wmem_overflow_bytes(&tiles, &used), 1024.0);
    }

    #[test]
    fn effective_bw_is_min_of_peak_and_demand() {
        // demand-limited
        let bw = effective_bandwidth(1e12, 1e6, 1000.0, 1000.0);
        assert!((bw - 1e6 / (1000.0 * 1e-9)).abs() / bw < 1e-12);
        // peak-limited
        let bw2 = effective_bandwidth(1e9, 1e9, 10.0, 1000.0);
        assert_eq!(bw2, 1e9);
    }

    #[test]
    fn pressure_eq17() {
        let p = pressure(800.0, 1000.0, 400.0, 1000.0);
        assert!((p - (0.8 + 0.5 * 0.4)).abs() < 1e-12);
    }

    #[test]
    fn peak_bw_scales_with_ports() {
        assert_eq!(
            tile_peak_bw(1024, 2, 1000.0),
            2.0 * tile_peak_bw(1024, 1, 1000.0)
        );
    }
}
