//! silicon-rl — RL-driven ASIC architecture exploration for on-device AI
//! inference.
//!
//! Reproduction of "From LLM to Silicon: RL-Driven ASIC Architecture
//! Exploration for On-Device AI Inference" (Ganti & Xu, CS.AR 2026).
//!
//! Three-layer architecture (see DESIGN.md):
//! * **L3 (this crate)** — the coordinator: workload IR, analytical PPA
//!   models, operator partitioning, the MDP environment, and the SAC +
//!   PER + world-model/MPC optimization loop of Algorithm 1.
//! * **L2/L1 (NN backends)** — every network call goes through the
//!   [`nn::backend::Backend`] trait: the pure-Rust [`nn::native`] kernels
//!   (no artifacts needed; the default when none are built) or the JAX
//!   networks built on a Pallas fused linear kernel, AOT-lowered to HLO
//!   text in `artifacts/` and executed through the PJRT CPU client
//!   ([`runtime`]). Python never runs on the optimization path.
//!
//! Entry points: [`rl::loop_::run_node`] optimizes one process node per
//! Algorithm 1; [`report`] regenerates every table/figure of the paper's
//! evaluation section. [`eval`] is the stateless, thread-parallel
//! evaluation layer beneath both (DESIGN.md §5): node sweeps, multi-seed
//! runs and MPC candidate scoring all fan out through
//! [`eval::Evaluator::evaluate_many`].

pub mod arch;
pub mod artifacts_out;
pub mod config;
pub mod env;
pub mod error;
pub mod eval;
pub mod hazard;
pub mod ir;
pub mod kv;
pub mod mem;
pub mod nn;
pub mod noc;
pub mod node;
pub mod partition;
pub mod ppa;
pub mod report;
pub mod rl;
pub mod runtime;
pub mod util;
