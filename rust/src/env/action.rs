//! Action space (Table 3): 30 continuous dims in [-1,1] (tanh-squashed
//! SAC head) + 4 discrete mesh/SC deltas in {-2..+2} (20 one-hot logits),
//! plus the constrained projection Π of Eq 68.
//!
//! Continuous layout (our concrete assignment of Table 3's groups; the
//! paper's row structure — 15 TCC dims, 4 memory/load, 3 op-partition, 2
//! streaming, 2 workload — is preserved, with the 4 remaining dims
//! carrying KV window, placement hop/centrality weights and duty cycle):
//!
//! | idx   | meaning                                      |
//! |-------|----------------------------------------------|
//! | 0–14  | TCC params: fetch, stanum, vlen, dmem, wmem, |
//! |       | imem, dflit, xr_wp, vr_wp, xdpnum, vdpnum,   |
//! |       | clock, precision, spec-decode, kv-compress   |
//! | 15–18 | memory/load: dmem-in frac, dmem-out frac,    |
//! |       | load weight, imbalance weight                |
//! | 19–21 | op partition deltas: matmul, conv, general   |
//! | 22–23 | streaming in/out                             |
//! | 24–25 | workload: sub-matmul split, all-reduce frac  |
//! | 26–29 | kv window, hop weight, centrality w, duty    |

pub const ACT_DIM: usize = 30;
pub const N_DISC: usize = 4;
pub const DISC_OPTIONS: usize = 5; // {-2,-1,0,+1,+2}
pub const DISC_DIM: usize = N_DISC * DISC_OPTIONS;

use crate::arch::{MeshConfig, ParamRanges, Precision, TccParams};
use crate::config::{ModeConfig, NodeBudget};
use crate::kv::KvStrategy;
use crate::mem::DmemSplit;
use crate::node::NodeSpec;
use crate::partition::PartitionKnobs;
use crate::util::clip;

/// A raw policy action: continuous vector + discrete delta choices.
#[derive(Debug, Clone)]
pub struct Action {
    pub cont: [f64; ACT_DIM],
    /// Mesh width/height and SC x/y deltas, each in -2..=2.
    pub deltas: [i32; N_DISC],
}

impl Action {
    pub fn neutral() -> Self {
        Action { cont: [0.0; ACT_DIM], deltas: [0; N_DISC] }
    }

    /// Decode discrete one-hot option index (0..5) to a delta (-2..=2).
    pub fn delta_from_option(opt: usize) -> i32 {
        opt as i32 - 2
    }
}

/// Everything the evaluation pipeline needs, decoded from an action.
#[derive(Debug, Clone)]
pub struct DecodedAction {
    pub mesh: MeshConfig,
    pub avg: TccParams,
    pub knobs: PartitionKnobs,
    pub dmem_split: DmemSplit,
    pub alpha_spec: f64,
    pub activity: f64,
    pub kv_strategy: KvStrategy,
}

/// Map a unit value in [-1,1] to [lo,hi] linearly.
fn unit_to(u: f64, lo: f64, hi: f64) -> f64 {
    lo + (clip(u, -1.0, 1.0) * 0.5 + 0.5) * (hi - lo)
}

/// Apply mesh deltas with bounds (mesh dims in [2,64], SC in [1,8]).
/// Reachable mesh side bounds: the Algorithm-1 walk clamps every
/// width/height delta into this range, so `[MESH_DIM_MIN, MESH_DIM_MAX]²`
/// brackets every mesh any action sequence can reach (the global roofline
/// envelope of `Evaluator::roofline_envelope` relies on this).
pub const MESH_DIM_MIN: u32 = 2;
pub const MESH_DIM_MAX: u32 = 64;

pub fn apply_deltas(mesh: &MeshConfig, deltas: &[i32; N_DISC]) -> MeshConfig {
    MeshConfig {
        width: (mesh.width as i32 + deltas[0]).clamp(MESH_DIM_MIN as i32, MESH_DIM_MAX as i32)
            as u32,
        height: (mesh.height as i32 + deltas[1])
            .clamp(MESH_DIM_MIN as i32, MESH_DIM_MAX as i32) as u32,
        sc_x: (mesh.sc_x as i32 + deltas[2]).clamp(1, 8) as u32,
        sc_y: (mesh.sc_y as i32 + deltas[3]).clamp(1, 8) as u32,
    }
}

/// Decode a raw action against the current mesh, node and mode.
pub fn decode(
    a: &Action,
    current_mesh: &MeshConfig,
    node: &NodeSpec,
    mode: &ModeConfig,
    ranges: &ParamRanges,
    base_kv: KvStrategy,
    seq_len: u32,
) -> DecodedAction {
    let c = &a.cont;
    let mesh = apply_deltas(current_mesh, &a.deltas);

    // --- clock: pinned to fmax in high-performance mode (§3.15)
    let clock_mhz = if let Some(f) = mode.clock_mhz_fixed {
        f
    } else if mode.pin_clock_to_fmax {
        node.fmax_mhz
    } else {
        unit_to(c[11], 10.0, node.fmax_mhz)
    };

    let precision = if c[12] > 0.5 { Precision::Int8 } else { Precision::Fp16 };

    let avg = TccParams {
        fetch: ranges.fetch.from_unit(c[0]),
        stanum: ranges.stanum.from_unit(c[1]),
        vlen_bits: ranges.vlen_bits.from_unit(c[2]),
        dmem_kb: ranges.dmem_kb.from_unit(c[3]),
        wmem_kb: ranges.wmem_kb.from_unit(c[4]),
        imem_kb: ranges.imem_kb.from_unit(c[5]),
        dflit_bits: ranges.dflit_bits.from_unit(c[6]),
        xr_wp: ranges.xr_wp.from_unit(c[7]),
        vr_wp: ranges.vr_wp.from_unit(c[8]),
        xdpnum: ranges.xdpnum.from_unit(c[9]),
        vdpnum: ranges.vdpnum.from_unit(c[10]),
        clock_mhz,
        precision,
    };

    let dmem_split = DmemSplit::new(unit_to(c[15], 0.1, 0.7), unit_to(c[16], 0.05, 0.5));

    let knobs = PartitionKnobs {
        rho_base: 0.3,
        d_matmul: unit_to(c[19], -0.3, 0.7),
        d_conv: unit_to(c[20], -0.3, 0.7),
        d_general: unit_to(c[21], -0.3, 0.3),
        w_load: unit_to(c[17], 0.2, 2.0),
        streaming_in: unit_to(c[22], 0.0, 1.0),
        streaming_out: unit_to(c[23], 0.0, 1.0),
        sub_matmul: unit_to(c[24], 0.0, 2.0),
        allreduce_frac: unit_to(c[25], 0.0, 1.0),
    };

    // speculative decoding α_spec (§3.8), gated by mode. Capped at 1.6
    // (the paper reports ~1.56×); the draft predictor's compute overhead
    // is charged in the power model, so α is not a free multiplier.
    let alpha_spec = if mode.alpha_spec > 1.0 {
        unit_to(c[13], 1.0, 1.6)
    } else {
        1.0
    };

    // duty cycle: high-perf streams at ~1.0; low-power may throttle
    let activity = (mode.activity * unit_to(c[29], 0.5, 1.5)).clamp(0.01, 1.0);

    // KV compression control (dim 14) upgrades the base strategy
    let kv_strategy = match base_kv {
        KvStrategy::Full if c[14] > 0.6 => KvStrategy::Quantized { bits: 8 },
        KvStrategy::Full if c[14] > 0.9 => KvStrategy::Quantized { bits: 4 },
        other => other,
    };
    let _ = seq_len; // window strategies carry their own token counts

    DecodedAction { mesh, avg, knobs, dmem_split, alpha_spec, activity, kv_strategy }
}

/// Constrained action projection Π_C (Eq 68): shrink the configuration
/// until a cheap closed-form power/area estimate fits the node budget.
/// Returns the projected decode and how many shrink steps were applied.
pub fn project(
    mut d: DecodedAction,
    node: &NodeSpec,
    budget: &NodeBudget,
    weight_bytes: f64,
) -> (DecodedAction, u32) {
    let mut steps = 0;
    for _ in 0..24 {
        let (p, a) = quick_estimate(&d, node, weight_bytes);
        if p <= budget.power_budget_mw && a <= budget.area_budget_mm2 {
            break;
        }
        // shrink the most effective lever: VLEN first, then mesh
        if d.avg.vlen_bits > 128 && steps % 2 == 0 {
            d.avg.vlen_bits /= 2;
        } else if d.mesh.width > 2 && d.mesh.height > 2 {
            d.mesh.width -= 1;
            d.mesh.height -= 1;
        } else if d.avg.vlen_bits > 128 {
            d.avg.vlen_bits /= 2;
        } else {
            break; // nothing left to shrink
        }
        steps += 1;
    }
    (d, steps)
}

/// Closed-form power/area estimate used by the projection (no placement;
/// assumes uniform tiles at the average parameters).
pub fn quick_estimate(d: &DecodedAction, node: &NodeSpec, weight_bytes: f64) -> (f64, f64) {
    let cores = d.mesh.cores() as f64;
    let lanes = d.avg.lanes();
    let f_hz = d.avg.clock_mhz * 1e6;
    let compute = cores * lanes * f_hz * node.mac_energy_pj * 1e-12 * d.activity * 1e3;
    let sram_mb = cores * (d.avg.dmem_kb + d.avg.imem_kb) as f64 / 1024.0;
    let sram_dyn = cores * (d.avg.clock_mhz / 1000.0) * node.sram_dyn_mw_per_core_ghz * d.activity;
    let weight_mb = weight_bytes / (1024.0 * 1024.0);
    let rom = weight_mb * node.rom_read_mw_per_mb_at_fmax * (d.avg.clock_mhz / node.fmax_mhz) * d.activity;
    // NoC estimate: DESIGN.md §6 traffic shape (∝ √cores)
    let leak = sram_mb * node.sram_leak_mw_per_mb;
    let noc = compute * 0.5; // upper-bound share per Table 12
    let power = compute + sram_dyn + rom + leak + noc;
    let area = cores * node.core_logic_mm2(lanes) + node.rom_mm2(weight_mb) + node.sram_mm2(sram_mb);
    (power, area)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::node::NodeTable;

    fn node3() -> NodeSpec {
        NodeTable::paper().get(3).unwrap().clone()
    }

    fn decode_neutral(mesh: MeshConfig) -> DecodedAction {
        decode(
            &Action::neutral(),
            &mesh,
            &node3(),
            &ModeConfig::high_performance(),
            &ParamRanges::paper(),
            KvStrategy::Full,
            2048,
        )
    }

    #[test]
    fn deltas_clamp_at_bounds() {
        let m = MeshConfig { width: 2, height: 64, sc_x: 1, sc_y: 8 };
        let out = apply_deltas(&m, &[-2, 2, -2, 2]);
        assert_eq!((out.width, out.height), (2, 64));
        assert_eq!((out.sc_x, out.sc_y), (1, 8));
    }

    #[test]
    fn neutral_action_decodes_mid_range() {
        let d = decode_neutral(MeshConfig::new(16, 16));
        assert_eq!(d.mesh.cores(), 256);
        // clock pinned to fmax in high-performance mode
        assert_eq!(d.avg.clock_mhz, 1000.0);
        assert!(d.avg.vlen_bits >= 128 && d.avg.vlen_bits <= 2048);
        assert!((d.knobs.rho_base - 0.3).abs() < 1e-12);
    }

    #[test]
    fn extreme_actions_stay_in_table7() {
        let r = ParamRanges::paper();
        for v in [-1.0f64, 1.0] {
            let mut a = Action::neutral();
            a.cont = [v; ACT_DIM];
            let d = decode(
                &a,
                &MeshConfig::new(8, 8),
                &node3(),
                &ModeConfig::high_performance(),
                &r,
                KvStrategy::Full,
                2048,
            );
            assert!((1..=16).contains(&d.avg.fetch));
            assert!((128..=2048).contains(&d.avg.vlen_bits));
            assert!((1..=32).contains(&d.avg.stanum));
            assert!((64..=8192).contains(&d.avg.dflit_bits));
        }
    }

    #[test]
    fn projection_enforces_budget_eq68() {
        // a deliberately over-budget design: giant mesh + max VLEN
        let mut a = Action::neutral();
        a.cont[2] = 1.0; // max vlen
        let d = decode(
            &a,
            &MeshConfig::new(64, 64),
            &node3(),
            &ModeConfig::high_performance(),
            &ParamRanges::paper(),
            KvStrategy::Full,
            2048,
        );
        let budget = ModeConfig::high_performance().budget(3).clone();
        let w = 14.96 * (1u64 << 30) as f64;
        let (proj, steps) = project(d, &node3(), &budget, w);
        assert!(steps > 0);
        let (p, ar) = quick_estimate(&proj, &node3(), w);
        assert!(
            p <= budget.power_budget_mw * 1.01 || proj.avg.vlen_bits == 128,
            "power {p} budget {}",
            budget.power_budget_mw
        );
        assert!(ar <= budget.area_budget_mm2 * 1.5, "area {ar}");
    }

    #[test]
    fn low_power_mode_fixes_10mhz() {
        let d = decode(
            &Action::neutral(),
            &MeshConfig::new(2, 4),
            &node3(),
            &ModeConfig::low_power(),
            &ParamRanges::paper(),
            KvStrategy::Full,
            1024,
        );
        assert_eq!(d.avg.clock_mhz, 10.0);
        assert_eq!(d.alpha_spec, 1.0);
        assert!(d.activity < 0.2);
    }

    #[test]
    fn kv_compression_action_upgrades_strategy() {
        let mut a = Action::neutral();
        a.cont[14] = 0.8;
        let d = decode(
            &a,
            &MeshConfig::new(4, 4),
            &node3(),
            &ModeConfig::high_performance(),
            &ParamRanges::paper(),
            KvStrategy::Full,
            2048,
        );
        assert_eq!(d.kv_strategy, KvStrategy::Quantized { bits: 8 });
    }
}
