//! The MDP environment (§3.1): action → configuration → partitioning →
//! heterogeneous derivation → analytical PPA → reward → next state.
//!
//! One [`Env`] instance optimizes one (workload, process-node) pair, as in
//! Algorithm 1's inner loop. `eval_action` is the "codegen + simulation"
//! step the paper quotes at ~10 ms — the episode hot path.

pub mod action;
pub mod reward;
pub mod state;

pub use action::{Action, DecodedAction, ACT_DIM, DISC_DIM, DISC_OPTIONS, N_DISC};
pub use reward::RewardTerms;
pub use state::{FULL_STATE_DIM, SAC_STATE_DIM};

use crate::arch::{self, MeshConfig, ParamRanges, TileConfig};
use crate::config::{Granularity, ModeConfig, NodeBudget, RunConfig};
use crate::hazard::Mitigation;
use crate::ir::stats::WorkloadStats;
use crate::ir::Graph;
use crate::kv::{self, KvStrategy};
use crate::node::{NodeSpec, NodeTable};
use crate::partition::{self, Placement, Unit};
use crate::ppa::{self, DesignPoint, PpaResult};

/// Full outcome of evaluating one action (one episode body).
#[derive(Debug, Clone)]
pub struct EvalOutcome {
    pub decoded: DecodedAction,
    pub tiles: Vec<TileConfig>,
    pub placement: Placement,
    pub ppa: PpaResult,
    pub reward: RewardTerms,
    pub full_state: [f64; FULL_STATE_DIM],
    /// Constraint-projection shrink steps applied (Eq 68).
    pub proj_steps: u32,
}

pub struct Env {
    pub graph: Graph,
    pub units: Vec<Unit>,
    pub wstats: WorkloadStats,
    pub node: NodeSpec,
    pub budget: NodeBudget,
    pub mode: ModeConfig,
    pub ranges: ParamRanges,
    pub kv_strategy: KvStrategy,
    pub seq_len: u32,
    pub batch_size: u32,
    /// Current mesh — the discrete action deltas walk this (Algorithm 1).
    pub mesh: MeshConfig,
}

impl Env {
    pub fn new(cfg: &RunConfig, nm: u32) -> Self {
        let graph = cfg.workload.build();
        let units = match cfg.granularity {
            Granularity::Op => partition::units_from_ops(&graph),
            Granularity::Group => partition::groups::units_from_groups(&graph),
        };
        let wstats = crate::ir::stats::compute(&graph);
        let table = NodeTable::paper();
        let node = table.get(nm).unwrap_or_else(|| panic!("unknown node {nm}nm")).clone();
        let budget = *cfg.mode.budget(nm);
        let mesh = initial_mesh(&graph, &cfg.mode);
        Env {
            graph,
            units,
            wstats,
            node,
            budget,
            mode: cfg.mode.clone(),
            ranges: ParamRanges::paper(),
            kv_strategy: cfg.kv_strategy,
            seq_len: cfg.workload.seq_len(),
            batch_size: 3, // paper's Llama evaluation batch (Table 9)
            mesh,
        }
    }

    /// Evaluate a raw action: the full §3.5 + §3.6–3.9 + §3.10 pipeline.
    /// Advances the environment's mesh to the (projected) action's mesh.
    pub fn eval_action(&mut self, a: &Action) -> EvalOutcome {
        // 1. decode + constraint projection (Eq 68)
        let decoded = action::decode(
            a,
            &self.mesh,
            &self.node,
            &self.mode,
            &self.ranges,
            self.kv_strategy,
            self.seq_len,
        );
        let total_weights = self.graph.total_weight_bytes();
        let (decoded, proj_steps) =
            action::project(decoded, &self.node, &self.budget, total_weights);

        // 2. operator partitioning + placement (§3.5)
        let mit = Mitigation {
            stanum: decoded.avg.stanum,
            fetch: decoded.avg.fetch,
            xr_wp: decoded.avg.xr_wp,
            vr_wp: decoded.avg.vr_wp,
        };
        let mut placement =
            partition::place_units(&self.units, &decoded.mesh, &decoded.knobs, &mit);

        // 3. KV-cache distribution across active tiles (Eq 27)
        let kv_total = match self.graph.kv {
            Some(kvc) => kv::total_bytes(&kvc, self.seq_len, decoded.kv_strategy),
            None => 0.0,
        };
        partition::distribute_kv(&mut placement.loads, kv_total);

        // 4. heterogeneous per-TCC derivation (§3.3)
        let tiles =
            arch::derive_tiles(&decoded.mesh, &decoded.avg, &placement.loads, &self.ranges);

        // 5. assemble the design point for the analytical models
        let d = self.design_point(&decoded, &placement, &tiles, total_weights);

        // 6. analytical PPA (Eqs 21-24, 62-64)
        let ppa_result = ppa::evaluate(&d, &self.node);

        // 7. feasibility + reward (Eqs 34-44)
        let mem_overflow = wmem_overflow(&tiles, &placement);
        let dmem_ok = dmem_feasible(&tiles, &placement, &decoded);
        let rterms = reward::compute(
            &self.mode.weights,
            &self.budget,
            &reward::RewardInputs {
                perf_gops: ppa_result.perf_gops,
                power_mw: ppa_result.power.total(),
                area_mm2: ppa_result.area.total(),
                mem_overflow_bytes: mem_overflow,
                dmem_ok,
                hazard_score: placement.hazards.score(),
            },
        );

        // 8. next state (Table 2)
        let full_state = state::encode_full(&state::StateInputs {
            workload: &self.wstats,
            mesh: &decoded.mesh,
            avg: &decoded.avg,
            node: &self.node,
            budget: &self.budget,
            placement: &placement,
            dmem_split: &decoded.dmem_split,
            ppa: Some(&ppa_result),
            hazards: &placement.hazards,
            kv_strategy: decoded.kv_strategy,
            seq_len: self.seq_len,
            weight_total_bytes: total_weights,
            batch_size: self.batch_size,
        });

        // 9. the mesh walk (Algorithm 1 line 8)
        self.mesh = decoded.mesh;

        EvalOutcome {
            decoded,
            tiles,
            placement,
            ppa: ppa_result,
            reward: rterms,
            full_state,
            proj_steps,
        }
    }

    fn design_point(
        &self,
        decoded: &DecodedAction,
        placement: &Placement,
        tiles: &[TileConfig],
        total_weights: f64,
    ) -> DesignPoint {
        let (sum_lanes, sum_lanes_capped) = DesignPoint::lane_sums(tiles);
        let sram_mb: f64 = tiles.iter().map(|t| t.sram_mb()).sum();

        // pipeline utilization η_util (Eq 63): hazards + memory pressure
        // + KV spill-to-WMEM latency (§3.9)
        let hazard = placement.hazards.density();
        let pressure_excess = mean_pressure_excess(tiles, placement);
        let spill = kv_spill_fraction(tiles, placement, decoded);
        let eta_util =
            (1.0 - 0.35 * hazard - 0.15 * pressure_excess - 0.2 * spill).clamp(0.3, 1.0);

        // per-token memory traffic: full weight sweep + compacted KV
        // (Eq 33) + cross-tile activations
        let kv_traffic = match self.graph.kv {
            Some(kvc) => kv::bytes_per_token(&kvc)
                / kv::compaction_factor(decoded.kv_strategy, self.seq_len),
            None => 0.0,
        };
        let mem_bytes_per_token =
            total_weights + kv_traffic + placement.traffic.cross_tile_bytes;

        // aggregate bandwidth: two ROM/SRAM ports of VLEN width per tile
        let f_hz = decoded.avg.clock_mhz * 1e6;
        let sum_bw_eff: f64 = tiles
            .iter()
            .map(|t| 2.0 * (t.vlen_bits as f64 / 8.0) * f_hz)
            .sum();

        DesignPoint {
            mesh: decoded.mesh,
            clock_mhz: decoded.avg.clock_mhz,
            dflit_bits: decoded.avg.dflit_bits,
            sum_lanes,
            sum_lanes_capped,
            sram_mb,
            weight_bytes: total_weights,
            traffic: placement.traffic.clone(),
            eta_parallel: placement.eta_parallel(),
            eta_util,
            alpha_spec: decoded.alpha_spec,
            flops_per_token: self.graph.flops_per_token_model(),
            mem_bytes_per_token,
            sum_bw_eff,
            activity: decoded.activity,
        }
    }
}

/// Initial mesh m₀(n) of Algorithm 1: sized so the model's weights fit at
/// mid-range WMEM, clamped to sensible walk-start bounds.
pub fn initial_mesh(graph: &Graph, mode: &ModeConfig) -> MeshConfig {
    let weights_mb = graph.total_weight_bytes() / (1024.0 * 1024.0);
    if mode.clock_mhz_fixed.is_some() {
        // low-power: start tiny
        return MeshConfig { width: 2, height: 2, sc_x: 1, sc_y: 1 };
    }
    // high-performance: start with ~16 MB of weights per tile
    let cores = (weights_mb / 16.0).ceil().max(4.0);
    let side = (cores.sqrt().ceil() as u32).clamp(2, 64);
    MeshConfig::new(side, side)
}

fn wmem_overflow(tiles: &[TileConfig], placement: &Placement) -> f64 {
    let used: Vec<f64> = placement.loads.iter().map(|l| l.weight_bytes).collect();
    crate::mem::wmem_overflow_bytes(tiles, &used)
}

/// Eq 27 feasibility: activation working sets must fit the DMEM
/// input+scratch partitions (≤5% violating tiles tolerated). KV overflow
/// is NOT an infeasibility — it spills to WMEM at a latency cost (§3.9),
/// handled by [`kv_spill_fraction`] throttling η_util.
fn dmem_feasible(tiles: &[TileConfig], placement: &Placement, d: &DecodedAction) -> bool {
    let mut violations = 0usize;
    let mut active = 0usize;
    for (t, l) in tiles.iter().zip(&placement.loads) {
        if l.flops <= 0.0 {
            continue;
        }
        active += 1;
        let dmem_bytes = t.dmem_kb as f64 * 1024.0;
        let usable = dmem_bytes * (d.dmem_split.input_frac + d.dmem_split.scratch_frac());
        // 4x headroom: moderate overflow streams from producers at a
        // latency cost (η_util pressure); only hopeless tiles violate
        if l.act_bytes > usable * 4.0 {
            violations += 1;
        }
    }
    active == 0 || (violations as f64) / (active as f64) <= 0.05
}

/// Fraction of active tiles whose KV slice does not fit the DMEM input
/// partition next to the activations — those slices spill to WMEM and pay
/// the slower-tier latency (§3.9), throttling η_util.
fn kv_spill_fraction(tiles: &[TileConfig], placement: &Placement, d: &DecodedAction) -> f64 {
    let mut spilled = 0usize;
    let mut active = 0usize;
    for (t, l) in tiles.iter().zip(&placement.loads) {
        if l.flops <= 0.0 {
            continue;
        }
        active += 1;
        let dmem_in = t.dmem_kb as f64 * 1024.0 * d.dmem_split.input_frac;
        if l.kv_bytes + l.act_bytes * 0.5 > dmem_in {
            spilled += 1;
        }
    }
    if active == 0 {
        0.0
    } else {
        spilled as f64 / active as f64
    }
}

fn mean_pressure_excess(tiles: &[TileConfig], placement: &Placement) -> f64 {
    let mut sum = 0.0;
    let mut n = 0usize;
    for (t, l) in tiles.iter().zip(&placement.loads) {
        if l.flops <= 0.0 {
            continue;
        }
        let p = crate::mem::pressure(
            l.weight_bytes,
            t.wmem_kb as f64 * 1024.0,
            l.act_bytes + l.kv_bytes,
            t.dmem_kb as f64 * 1024.0,
        );
        sum += (p - 1.0).max(0.0);
        n += 1;
    }
    if n == 0 {
        0.0
    } else {
        (sum / n as f64).min(1.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::RunConfig;

    fn small_cfg() -> RunConfig {
        let mut c = RunConfig::default();
        c.granularity = Granularity::Group;
        c
    }

    #[test]
    fn eval_neutral_action_is_finite_and_consistent() {
        let mut env = Env::new(&small_cfg(), 3);
        let out = env.eval_action(&Action::neutral());
        assert!(out.ppa.tokens_per_s.is_finite() && out.ppa.tokens_per_s > 0.0);
        assert!(out.ppa.power.total() > 0.0);
        assert!(out.ppa.area.total() > 0.0);
        assert!(out.reward.total.is_finite());
        assert!(out.full_state.iter().all(|v| v.is_finite()));
        assert_eq!(out.tiles.len(), out.decoded.mesh.cores());
    }

    #[test]
    fn mesh_walks_with_deltas() {
        let mut env = Env::new(&small_cfg(), 7);
        let w0 = env.mesh.width;
        let mut a = Action::neutral();
        a.deltas = [2, 2, 0, 0];
        env.eval_action(&a);
        // projection may shrink, but without violation the walk is +2
        assert!(env.mesh.width >= w0, "{} -> {}", w0, env.mesh.width);
    }

    #[test]
    fn smolvlm_low_power_under_budget_at_3nm() {
        let mut cfg = RunConfig::smolvlm_low_power();
        cfg.granularity = Granularity::Group;
        let mut env = Env::new(&cfg, 3);
        // a power-aware action: small DMEM/IMEM (the RL converges here;
        // this pins the reachable operating point of Table 19)
        let mut a = Action::neutral();
        a.cont[3] = -1.0; // min DMEM
        a.cont[5] = -0.5; // small IMEM
        a.cont[19] = 1.0; // spread matmuls wide: smaller per-tile slices
        let out = env.eval_action(&a);
        // §4.12: a small mesh at 10 MHz lands in the low-mW regime even
        // for this hand-built action; the RL search drives it < 13 mW
        // (validated by bench_nodes' SmolVLM sweep)
        assert!(
            out.ppa.power.total() < 16.0,
            "power {} mW",
            out.ppa.power.total()
        );
        // leakage-dominated at 3nm (paper: 97%)
        let leak_share = out.ppa.power.leakage / out.ppa.power.total();
        assert!(leak_share > 0.7, "leak share {leak_share}");
        assert_eq!(out.decoded.avg.clock_mhz, 10.0);
    }

    #[test]
    fn initial_mesh_scales_with_workload() {
        let llama = crate::ir::llama::build();
        let smol = crate::ir::smolvlm::build();
        let hp = ModeConfig::high_performance();
        let m_l = initial_mesh(&llama, &hp);
        let m_s = initial_mesh(&smol, &hp);
        assert!(m_l.cores() > m_s.cores());
    }

    #[test]
    fn reward_improves_when_perf_grows_within_budget() {
        // bigger vlen within budget should not lower reward's perf term
        let mut env = Env::new(&small_cfg(), 3);
        let mut small = Action::neutral();
        small.cont[2] = -1.0; // min vlen
        let r_small = env.eval_action(&small);
        let mut env2 = Env::new(&small_cfg(), 3);
        let mut big = Action::neutral();
        big.cont[2] = 0.5;
        let r_big = env2.eval_action(&big);
        assert!(r_big.ppa.perf_gops > r_small.ppa.perf_gops);
    }

    #[test]
    fn state_dims_match_table2() {
        let mut env = Env::new(&small_cfg(), 3);
        let out = env.eval_action(&Action::neutral());
        assert_eq!(out.full_state.len(), 73);
        let sub = state::sac_subset(&out.full_state);
        assert_eq!(sub.len(), 52);
    }
}
