//! The MDP environment (§3.1): a thin stateful wrapper over the
//! stateless evaluation layer ([`crate::eval`]).
//!
//! One [`Env`] instance optimizes one (workload, process-node) pair, as in
//! Algorithm 1's inner loop. All of the action → configuration →
//! partitioning → heterogeneous derivation → analytical PPA → reward →
//! next-state pipeline lives in [`Evaluator::evaluate`] — a pure function
//! (stage-split and per-stage memoized, DESIGN.md §5) that fans out
//! across cores. The environment owns exactly the mutable part: the
//! walking mesh (Algorithm 1 line 8) plus a reusable [`EvalScratch`]
//! whose placement-stage memo stays warm across the walk, so
//! `eval_action` stays allocation-free and continuous-knob steps skip
//! the O(units × cores) placement.

pub mod action;
pub mod reward;
pub mod state;

pub use action::{Action, DecodedAction, ACT_DIM, DISC_DIM, DISC_OPTIONS, N_DISC};
pub use reward::RewardTerms;
pub use state::{FULL_STATE_DIM, SAC_STATE_DIM};

// Re-exported for source compatibility: the outcome type and initial-mesh
// rule moved to the evaluation layer.
pub use crate::eval::{initial_mesh, EvalOutcome};

use crate::arch::MeshConfig;
use crate::config::RunConfig;
use crate::eval::{EvalScratch, Evaluator};

pub struct Env {
    /// The immutable evaluation context (graph, units, node, budget, …).
    /// Also reachable field-by-field through `Deref`, so `env.node`,
    /// `env.budget` etc. keep working.
    pub eval: Evaluator,
    /// Current mesh — the discrete action deltas walk this (Algorithm 1).
    pub mesh: MeshConfig,
    scratch: EvalScratch,
}

impl std::ops::Deref for Env {
    type Target = Evaluator;

    fn deref(&self) -> &Evaluator {
        &self.eval
    }
}

impl Env {
    pub fn new(cfg: &RunConfig, nm: u32) -> Self {
        let eval = Evaluator::new(cfg, nm);
        let mesh = eval.initial_mesh();
        Env { eval, mesh, scratch: EvalScratch::default() }
    }

    /// Evaluate a raw action: the full §3.5 + §3.6–3.9 + §3.10 pipeline.
    /// Advances the environment's mesh to the (projected) action's mesh.
    pub fn eval_action(&mut self, a: &Action) -> EvalOutcome {
        let out = self.eval.evaluate(&self.mesh, a, &mut self.scratch);
        // the mesh walk (Algorithm 1 line 8)
        self.mesh = out.decoded.mesh;
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{Granularity, ModeConfig, RunConfig};

    fn small_cfg() -> RunConfig {
        let mut c = RunConfig::default();
        c.granularity = Granularity::Group;
        c
    }

    #[test]
    fn eval_neutral_action_is_finite_and_consistent() {
        let mut env = Env::new(&small_cfg(), 3);
        let out = env.eval_action(&Action::neutral());
        assert!(out.ppa.tokens_per_s.is_finite() && out.ppa.tokens_per_s > 0.0);
        assert!(out.ppa.power.total() > 0.0);
        assert!(out.ppa.area.total() > 0.0);
        assert!(out.reward.total.is_finite());
        assert!(out.full_state.iter().all(|v| v.is_finite()));
        assert_eq!(out.tiles.len(), out.decoded.mesh.cores());
    }

    #[test]
    fn mesh_walks_with_deltas() {
        let mut env = Env::new(&small_cfg(), 7);
        let w0 = env.mesh.width;
        let mut a = Action::neutral();
        a.deltas = [2, 2, 0, 0];
        env.eval_action(&a);
        // projection may shrink, but without violation the walk is +2
        assert!(env.mesh.width >= w0, "{} -> {}", w0, env.mesh.width);
    }

    #[test]
    fn smolvlm_low_power_under_budget_at_3nm() {
        let mut cfg = RunConfig::smolvlm_low_power();
        cfg.granularity = Granularity::Group;
        let mut env = Env::new(&cfg, 3);
        // a power-aware action: small DMEM/IMEM (the RL converges here;
        // this pins the reachable operating point of Table 19)
        let mut a = Action::neutral();
        a.cont[3] = -1.0; // min DMEM
        a.cont[5] = -0.5; // small IMEM
        a.cont[19] = 1.0; // spread matmuls wide: smaller per-tile slices
        let out = env.eval_action(&a);
        // §4.12: a small mesh at 10 MHz lands in the low-mW regime even
        // for this hand-built action; the RL search drives it < 13 mW
        // (validated by bench_nodes' SmolVLM sweep)
        assert!(
            out.ppa.power.total() < 16.0,
            "power {} mW",
            out.ppa.power.total()
        );
        // leakage-dominated at 3nm (paper: 97%)
        let leak_share = out.ppa.power.leakage / out.ppa.power.total();
        assert!(leak_share > 0.7, "leak share {leak_share}");
        assert_eq!(out.decoded.avg.clock_mhz, 10.0);
    }

    #[test]
    fn initial_mesh_scales_with_workload() {
        let llama = crate::ir::llama::build();
        let smol = crate::ir::smolvlm::build();
        let hp = ModeConfig::high_performance();
        let m_l = initial_mesh(&llama, &hp);
        let m_s = initial_mesh(&smol, &hp);
        assert!(m_l.cores() > m_s.cores());
    }

    #[test]
    fn reward_improves_when_perf_grows_within_budget() {
        // bigger vlen within budget should not lower reward's perf term
        let mut env = Env::new(&small_cfg(), 3);
        let mut small = Action::neutral();
        small.cont[2] = -1.0; // min vlen
        let r_small = env.eval_action(&small);
        let mut env2 = Env::new(&small_cfg(), 3);
        let mut big = Action::neutral();
        big.cont[2] = 0.5;
        let r_big = env2.eval_action(&big);
        assert!(r_big.ppa.perf_gops > r_small.ppa.perf_gops);
    }

    #[test]
    fn state_dims_match_table2() {
        let mut env = Env::new(&small_cfg(), 3);
        let out = env.eval_action(&Action::neutral());
        assert_eq!(out.full_state.len(), 73);
        let sub = state::sac_subset(&out.full_state);
        assert_eq!(sub.len(), 52);
    }

    #[test]
    fn env_wrapper_matches_direct_evaluator() {
        // the wrapper must be a zero-logic shim over the eval layer
        let cfg = small_cfg();
        let mut env = Env::new(&cfg, 3);
        let ev = Evaluator::new(&cfg, 3);
        let mut scratch = EvalScratch::default();
        let mut mesh = ev.initial_mesh();
        let mut a = Action::neutral();
        a.deltas = [1, -1, 0, 0];
        for _ in 0..3 {
            let from_env = env.eval_action(&a);
            let direct = ev.evaluate(&mesh, &a, &mut scratch);
            mesh = direct.decoded.mesh;
            assert_eq!(
                from_env.reward.total.to_bits(),
                direct.reward.total.to_bits()
            );
            assert_eq!(from_env.decoded.mesh, direct.decoded.mesh);
            assert_eq!(env.mesh, mesh);
        }
    }
}
