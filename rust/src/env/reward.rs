//! Reward function (§3.10, Eqs 34–44 and Table 4).
//!
//!   R = α·P_norm − β·P_power − γ·A_norm + B_feasible
//!       − P_violation − P_memory − P_hazard

use crate::config::NodeBudget;
use crate::ppa::score::{ppa_score, NormRanges, PpaWeights};

/// Score magnitude s_mag (Table 4's feasibility bonus scale). Kept small
/// relative to the PPA terms so the Eq 38 power-margin bonus cannot
/// dominate the performance objective in high-performance mode.
pub const S_MAG: f64 = 0.25;
/// λ_mem of Eq 40 (per GB of overflow).
pub const LAMBDA_MEM: f64 = 0.5;
/// λ_hazard of Eq 41.
pub const LAMBDA_HAZARD: f64 = 0.1;

/// Reward terms, kept separate for logging / Table 4 verification.
#[derive(Debug, Clone, Copy, Default)]
pub struct RewardTerms {
    pub p_norm: f64,
    pub p_power: f64,
    pub a_norm: f64,
    pub b_feasible: f64,
    pub p_violation: f64,
    pub p_memory: f64,
    pub p_hazard: f64,
    pub total: f64,
    pub feasible: bool,
    /// Lower-is-better composite PPA score (Table 10 column).
    pub score: f64,
}

/// Inputs to the reward computation for one evaluated design.
#[derive(Debug, Clone, Copy)]
pub struct RewardInputs {
    pub perf_gops: f64,
    pub power_mw: f64,
    pub area_mm2: f64,
    /// WMEM overflow in bytes (Eq 14 violation; 0 when feasible).
    pub mem_overflow_bytes: f64,
    /// DMEM/KV feasibility (Eq 27): true when KV + activations fit.
    pub dmem_ok: bool,
    /// Hazard score in [0,1] (Eq 41's TotalHazardScore).
    pub hazard_score: f64,
}

/// Normalization ranges from the node budget (§3.10: "derived from
/// process node characteristics and constraints").
pub fn ranges_from_budget(b: &NodeBudget) -> NormRanges {
    NormRanges {
        perf_min: 0.0,
        perf_max: b.perf_max_gops,
        power_min: 0.0,
        power_max: b.power_budget_mw,
        area_min: 0.0,
        area_max: b.area_budget_mm2,
    }
}

pub fn compute(w: &PpaWeights, budget: &NodeBudget, inp: &RewardInputs) -> RewardTerms {
    let ranges = ranges_from_budget(budget);
    let (alpha, beta, gamma) = w.normalized();
    let (p_norm, p_power, a_norm) =
        ranges.normalize(inp.perf_gops, inp.power_mw, inp.area_mm2);

    // --- feasibility: power & area within budget, memory constraints met
    let power_ok = inp.power_mw <= budget.power_budget_mw;
    let area_ok = inp.area_mm2 <= budget.area_budget_mm2;
    let mem_ok = inp.mem_overflow_bytes <= 0.0 && inp.dmem_ok;
    let feasible = power_ok && area_ok && mem_ok;

    // Eq 38: B = s_mag (1 + m_pwr), m_pwr = (P_budget - P)/P_budget
    let b_feasible = if feasible {
        let m_pwr = (budget.power_budget_mw - inp.power_mw) / budget.power_budget_mw;
        S_MAG * (1.0 + m_pwr)
    } else {
        0.0
    };

    // Eq 39: cubic power-violation penalty
    let p_violation = if !power_ok {
        let v = (inp.power_mw - budget.power_budget_mw) / budget.power_budget_mw;
        S_MAG * (1.0 + v) * v * v
    } else if !area_ok {
        // area violation shaped the same way (constraint set of Eq 68)
        let v = (inp.area_mm2 - budget.area_budget_mm2) / budget.area_budget_mm2;
        S_MAG * (1.0 + v) * v * v
    } else {
        0.0
    };

    // Eq 40: linear memory-overuse penalty (per GB)
    let p_memory = LAMBDA_MEM * (inp.mem_overflow_bytes / 1e9).max(0.0)
        + if inp.dmem_ok { 0.0 } else { 0.25 };

    // Eq 41
    let p_hazard = LAMBDA_HAZARD * inp.hazard_score.clamp(0.0, 1.0);

    let total = alpha * p_norm - beta * p_power - gamma * a_norm + b_feasible
        - p_violation
        - p_memory
        - p_hazard;

    let score = ppa_score(w, &ranges, inp.perf_gops, inp.power_mw, inp.area_mm2);

    RewardTerms {
        p_norm,
        p_power,
        a_norm,
        b_feasible,
        p_violation,
        p_memory,
        p_hazard,
        total,
        feasible,
        score,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn budget() -> NodeBudget {
        NodeBudget {
            nm: 3,
            power_budget_mw: 50_000.0,
            area_budget_mm2: 700.0,
            perf_max_gops: 3_000_000.0,
        }
    }

    fn feasible_inputs() -> RewardInputs {
        RewardInputs {
            perf_gops: 400_000.0,
            power_mw: 45_000.0,
            area_mm2: 650.0,
            mem_overflow_bytes: 0.0,
            dmem_ok: true,
            hazard_score: 0.1,
        }
    }

    #[test]
    fn feasible_gets_bonus_infeasible_does_not() {
        let w = PpaWeights::HIGH_PERF;
        let ok = compute(&w, &budget(), &feasible_inputs());
        assert!(ok.feasible && ok.b_feasible > S_MAG);
        let mut bad = feasible_inputs();
        bad.power_mw = 60_000.0;
        let r = compute(&w, &budget(), &bad);
        assert!(!r.feasible && r.b_feasible == 0.0 && r.p_violation > 0.0);
        assert!(r.total < ok.total);
    }

    #[test]
    fn violation_penalty_is_cubic_eq39() {
        let w = PpaWeights::HIGH_PERF;
        let mut a = feasible_inputs();
        a.power_mw = 55_000.0; // v = 0.1
        let mut b = feasible_inputs();
        b.power_mw = 60_000.0; // v = 0.2
        let ra = compute(&w, &budget(), &a);
        let rb = compute(&w, &budget(), &b);
        // (1+0.2)*0.04 / (1+0.1)*0.01 ≈ 4.36x
        let ratio = rb.p_violation / ra.p_violation;
        assert!((ratio - (1.2 * 0.04) / (1.1 * 0.01)).abs() < 1e-9, "ratio {ratio}");
    }

    #[test]
    fn memory_overflow_penalized_linearly_eq40() {
        let w = PpaWeights::HIGH_PERF;
        let mut a = feasible_inputs();
        a.mem_overflow_bytes = 2e9;
        let r = compute(&w, &budget(), &a);
        assert!((r.p_memory - 1.0).abs() < 1e-12);
        assert!(!r.feasible);
    }

    #[test]
    fn higher_perf_higher_reward() {
        let w = PpaWeights::HIGH_PERF;
        let lo = compute(&w, &budget(), &feasible_inputs());
        let mut hi_in = feasible_inputs();
        hi_in.perf_gops *= 2.0;
        let hi = compute(&w, &budget(), &hi_in);
        assert!(hi.total > lo.total);
        assert!(hi.score < lo.score); // lower-is-better score improves too
    }

    #[test]
    fn reward_in_typical_table4_range() {
        let r = compute(&PpaWeights::HIGH_PERF, &budget(), &feasible_inputs());
        assert!(r.total > -5.0 && r.total < 3.0, "total {}", r.total);
    }

    #[test]
    fn power_margin_increases_bonus_eq38() {
        let w = PpaWeights::HIGH_PERF;
        let mut frugal = feasible_inputs();
        frugal.power_mw = 10_000.0;
        let rf = compute(&w, &budget(), &frugal);
        let rn = compute(&w, &budget(), &feasible_inputs());
        assert!(rf.b_feasible > rn.b_feasible);
    }

    #[test]
    fn hazard_penalty_scaled_eq41() {
        let w = PpaWeights::HIGH_PERF;
        let mut h = feasible_inputs();
        h.hazard_score = 1.0;
        let r = compute(&w, &budget(), &h);
        assert!((r.p_hazard - LAMBDA_HAZARD).abs() < 1e-12);
    }
}
