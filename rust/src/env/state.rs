//! State representation (Table 2): the full 73-dim vector and the 52-dim
//! optimized subset the SAC actor consumes.
//!
//! Every feature is normalized to roughly [0, 1] so the MLP actor sees a
//! well-conditioned input. The paper does not enumerate which 21 features
//! are dropped for the 52-dim subset; we drop redundant/static ones
//! (precision one-hots, port counts, duplicated node id, LLM-config
//! constants) — the list is pinned in [`SAC_DROPPED`].

use crate::arch::{MeshConfig, TccParams};
use crate::config::NodeBudget;
use crate::hazard::HazardStats;
use crate::ir::stats::WorkloadStats;
use crate::kv::KvStrategy;
use crate::mem::DmemSplit;
use crate::node::NodeSpec;
use crate::noc::NocModel;
use crate::partition::Placement;
use crate::ppa::PpaResult;

pub const FULL_STATE_DIM: usize = 73;
pub const SAC_STATE_DIM: usize = 52;

/// The 21 feature indices excluded from the SAC subset.
pub const SAC_DROPPED: [usize; 21] = [
    10, // imem config (derived per-tile anyway)
    12, 13, 14, 15, // register/dispatch port counts
    16, // precision flag (duplicated by dims 59-64)
    20, // node nm (constant within a node's optimization run)
    36, // general-partition ratio (≈ constant)
    44, // per-TCC hazard std
    49, // sub-matmul knob echo
    55, // active-fraction duplicate
    58, // per-tile KV echo
    59, 60, 61, 62, 63, 64, // precision distribution one-hots
    70, 71, 72, // LLM config (fixed per run)
];

/// Everything the encoder reads. Assembled once per episode.
pub struct StateInputs<'a> {
    pub workload: &'a WorkloadStats,
    pub mesh: &'a MeshConfig,
    pub avg: &'a TccParams,
    pub node: &'a NodeSpec,
    pub budget: &'a NodeBudget,
    pub placement: &'a Placement,
    pub dmem_split: &'a DmemSplit,
    pub ppa: Option<&'a PpaResult>,
    pub hazards: &'a HazardStats,
    pub kv_strategy: KvStrategy,
    pub seq_len: u32,
    pub weight_total_bytes: f64,
    pub batch_size: u32,
}

/// Encode the full 73-dim state vector (Table 2 layout).
pub fn encode_full(inp: &StateInputs) -> [f64; FULL_STATE_DIM] {
    let mut s = [0.0f64; FULL_STATE_DIM];
    let w = inp.workload;
    let mesh = inp.mesh;
    let avg = inp.avg;
    let cores = mesh.cores() as f64;

    // --- 0-4 workload
    s[0] = (w.instr_count.max(1.0).log10() / 10.0).min(1.0);
    s[1] = (w.ilp / 64.0).min(1.0);
    s[2] = w.mem_intensity.min(4.0) / 4.0;
    s[3] = w.vector_util;
    s[4] = w.matmul_ratio;

    // --- 5-25 configuration (21 dims)
    s[5] = mesh.width as f64 / 64.0;
    s[6] = mesh.height as f64 / 64.0;
    s[7] = mesh.sc_x as f64 / 8.0;
    s[8] = mesh.sc_y as f64 / 8.0;
    s[9] = avg.fetch as f64 / 16.0;
    s[10] = avg.imem_kb as f64 / 128.0;
    s[11] = avg.stanum as f64 / 32.0;
    s[12] = avg.xr_wp as f64 / 16.0;
    s[13] = avg.vr_wp as f64 / 16.0;
    s[14] = avg.xdpnum as f64 / 16.0;
    s[15] = avg.vdpnum as f64 / 16.0;
    s[16] = match avg.precision {
        crate::arch::Precision::Fp16 => 0.0,
        crate::arch::Precision::Int8 => 1.0,
    };
    s[17] = avg.vlen_bits as f64 / 2048.0;
    s[18] = avg.dmem_kb as f64 / 1024.0;
    s[19] = (avg.wmem_kb as f64 / 131_072.0).min(1.0);
    s[20] = inp.node.nm as f64 / 28.0;
    s[21] = avg.dflit_bits as f64 / 8192.0;
    s[22] = cores / 4096.0;
    s[23] = (inp.weight_total_bytes / (16.0 * (1u64 << 30) as f64)).min(1.0);
    s[24] = avg.clock_mhz / inp.node.fmax_mhz;
    s[25] = (inp.placement.n_units as f64 / 8192.0).min(1.0);

    // --- 26-28 DMEM partitioning
    s[26] = inp.dmem_split.input_frac;
    s[27] = inp.dmem_split.output_frac;
    s[28] = inp.dmem_split.scratch_frac();

    // --- 29-32 load distribution
    let ls = &inp.placement.load_stats;
    s[29] = ((ls.variance.max(1.0)).log10() / 20.0).min(1.0);
    s[30] = if ls.max_min_ratio.is_finite() { (ls.max_min_ratio / 10.0).min(1.0) } else { 1.0 };
    s[31] = ls.balance;
    s[32] = (ls.mean.max(1.0).log10() / 12.0).min(1.0);

    // --- 33-36 op partitioning (Eq 10-13 realized ratios)
    s[33] = inp.placement.class_rho[0];
    s[34] = inp.placement.class_rho[1];
    s[35] = inp.placement.class_rho[2];
    s[36] = inp.placement.class_rho.iter().sum::<f64>() / 3.0;

    // --- 37-40 global hazards
    let hz = inp.hazards;
    let per_i = |x: f64| if hz.instrs > 0.0 { (x / hz.instrs).min(1.0) } else { 0.0 };
    s[37] = per_i(hz.raw);
    s[38] = per_i(hz.war);
    s[39] = per_i(hz.waw);
    s[40] = hz.density();

    // --- 41-44 per-TCC hazard aggregates (weighted by per-tile instrs)
    let (mut hmin, mut hmax, mut hsum, mut hsq) = (f64::INFINITY, 0.0f64, 0.0, 0.0);
    let mean_instr =
        inp.placement.loads.iter().map(|l| l.instrs).sum::<f64>() / cores.max(1.0);
    for l in &inp.placement.loads {
        let d = hz.density() * (l.instrs / mean_instr.max(1.0)).min(2.0);
        hmin = hmin.min(d);
        hmax = hmax.max(d);
        hsum += d;
        hsq += d * d;
    }
    let hmean = hsum / cores.max(1.0);
    s[41] = hmean.min(1.0);
    s[42] = hmax.min(1.0);
    s[43] = if hmin.is_finite() { hmin.min(1.0) } else { 0.0 };
    s[44] = (hsq / cores.max(1.0) - hmean * hmean).max(0.0).sqrt().min(1.0);

    // --- 45 frequency
    s[45] = avg.clock_mhz / inp.node.fmax_mhz;

    // --- 46-49 streaming / pipeline
    s[46] = inp.placement.traffic.cross_tile_bytes.max(1.0).log10() / 12.0;
    s[47] = (inp.placement.traffic.mean_hops() / 40.0).min(1.0);
    s[48] = (avg.fetch as f64 * avg.vdpnum as f64 / 64.0).min(1.0);
    s[49] = (inp.placement.traffic.n_transfers as f64 / 1e5).min(1.0);

    // --- 50-54 PPA observation (surrogate feedback)
    if let Some(p) = inp.ppa {
        s[50] = (p.power.total() / inp.budget.power_budget_mw).min(2.0) / 2.0;
        s[51] = (p.perf_gops / inp.budget.perf_max_gops).min(1.0);
        s[52] = (p.area.total() / inp.budget.area_budget_mm2).min(2.0) / 2.0;
        s[53] = (p.tokens_per_s.max(1.0).log10() / 6.0).min(1.0);
        s[54] = (p.perf_gops / p.power.total().max(1e-9) / 20.0).min(1.0);
    }

    // --- 55-58 workload partition statistics
    let active = inp.placement.loads.iter().filter(|l| l.flops > 0.0).count() as f64;
    s[55] = active / cores.max(1.0);
    let wmax = inp.placement.loads.iter().map(|l| l.weight_bytes).fold(0.0, f64::max);
    s[56] = if wmax > 0.0 {
        inp.placement.loads.iter().map(|l| l.weight_bytes).sum::<f64>()
            / (wmax * cores.max(1.0))
    } else {
        0.0
    };
    s[57] = ls.balance;
    s[58] = (inp.placement.loads.iter().map(|l| l.act_bytes).fold(0.0, f64::max)
        / (1024.0 * 1024.0))
        .min(1.0);

    // --- 59-64 precision distribution (fp32, fp16, bf16, fp8, int8, mixed)
    match avg.precision {
        crate::arch::Precision::Fp16 => s[60] = 1.0,
        crate::arch::Precision::Int8 => s[63] = 1.0,
    }

    // --- 65-66 instruction type ratios
    s[65] = w.scalar_ratio;
    s[66] = w.vector_ratio;

    // --- 67-69 SC topology
    let noc = NocModel { mesh: *mesh, dflit_bits: avg.dflit_bits, clock_mhz: avg.clock_mhz };
    s[67] = active / 4096.0;
    s[68] = (noc.mean_hops_effective() / 40.0).min(1.0);
    s[69] = (noc.mean_latency_s() * 1e7).min(1.0);

    // --- 70-72 LLM config
    s[70] = (inp.batch_size as f64 / 8.0).min(1.0);
    s[71] = match inp.kv_strategy {
        KvStrategy::Full => 0.0,
        KvStrategy::Quantized { .. } => 0.25,
        KvStrategy::Window { .. } => 0.5,
        KvStrategy::QuantizedWindow { .. } => 0.75,
        KvStrategy::Paged { .. } => 1.0,
    };
    s[72] = 1.0 / crate::kv::compaction_factor(inp.kv_strategy, inp.seq_len);

    s
}

/// Index of a full-state feature within the 52-dim SAC subset, or None
/// if dropped. Used by the MPC planner to read the PPA-observation dims
/// (50–54) out of world-model predicted states.
pub fn subset_index(full_idx: usize) -> Option<usize> {
    if SAC_DROPPED.contains(&full_idx) {
        return None;
    }
    Some(full_idx - SAC_DROPPED.iter().filter(|&&d| d < full_idx).count())
}

/// Project the full state onto the 52-dim SAC subset.
pub fn sac_subset(full: &[f64; FULL_STATE_DIM]) -> [f32; SAC_STATE_DIM] {
    let mut out = [0.0f32; SAC_STATE_DIM];
    let mut j = 0;
    for (i, &v) in full.iter().enumerate() {
        if !SAC_DROPPED.contains(&i) {
            out[j] = v as f32;
            j += 1;
        }
    }
    debug_assert_eq!(j, SAC_STATE_DIM);
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dropped_list_is_consistent() {
        assert_eq!(SAC_DROPPED.len(), FULL_STATE_DIM - SAC_STATE_DIM);
        let mut sorted = SAC_DROPPED.to_vec();
        sorted.sort_unstable();
        sorted.dedup();
        assert_eq!(sorted.len(), 21, "duplicate indices in SAC_DROPPED");
        assert!(sorted.iter().all(|&i| i < FULL_STATE_DIM));
    }

    #[test]
    fn subset_index_round_trips() {
        let mut full = [0.0f64; FULL_STATE_DIM];
        for (i, v) in full.iter_mut().enumerate() {
            *v = i as f64;
        }
        let sub = sac_subset(&full);
        for i in 0..FULL_STATE_DIM {
            match subset_index(i) {
                Some(j) => assert_eq!(sub[j] as usize, i),
                None => assert!(SAC_DROPPED.contains(&i)),
            }
        }
        // PPA observation dims survive the subset (MPC depends on them)
        for i in 50..=54 {
            assert!(subset_index(i).is_some(), "dim {i} dropped");
        }
    }

    #[test]
    fn subset_extraction_preserves_order() {
        let mut full = [0.0f64; FULL_STATE_DIM];
        for (i, v) in full.iter_mut().enumerate() {
            *v = i as f64;
        }
        let sub = sac_subset(&full);
        assert_eq!(sub.len(), 52);
        // first kept index is 0, values strictly increasing
        assert_eq!(sub[0], 0.0);
        for w in sub.windows(2) {
            assert!(w[1] > w[0]);
        }
    }
}
