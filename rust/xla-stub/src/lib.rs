//! Offline stub of the vendored `xla-rs` PJRT bindings.
//!
//! This image does not ship the XLA C++ libraries, so the real bindings
//! cannot link. This crate mirrors the API surface `silicon_rl::runtime`
//! uses — client/executable/buffer/literal types with identical method
//! signatures — and degrades gracefully:
//!
//! * [`Literal`] is fully functional (host-side data, no device): the
//!   scalar/vec1/reshape/to_vec plumbing the runtime tests exercise works.
//! * Device paths ([`PjRtClient::compile`], execution) return
//!   [`Error::Unavailable`] with a clear message. Callers gate on
//!   [`backend_available`] and skip artifact-dependent work.
//!
//! Swapping in the real bindings is a `Cargo.toml` path change in the
//! `silicon_rl` package; no call site changes.

use std::fmt;

/// True when this build can actually execute HLO. The stub never can.
pub const fn backend_available() -> bool {
    false
}

const UNAVAILABLE_MSG: &str =
    "PJRT backend unavailable: this build uses the offline xla stub \
     (vendor the real xla-rs bindings to execute HLO artifacts)";

/// Error type mirroring xla-rs (message-carrying).
#[derive(Debug, Clone)]
pub struct Error {
    msg: String,
}

impl Error {
    pub fn msg(m: impl fmt::Display) -> Error {
        Error { msg: m.to_string() }
    }

    fn unavailable() -> Error {
        Error::msg(UNAVAILABLE_MSG)
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.msg)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

/// Element types a [`Literal`] / device buffer can carry. The runtime only
/// ever moves flat `f32` data, so that is the only implementation.
pub trait ArrayElement: Copy {
    fn from_f32(v: f32) -> Self;
    fn into_f32(self) -> f32;
}

impl ArrayElement for f32 {
    fn from_f32(v: f32) -> Self {
        v
    }

    fn into_f32(self) -> f32 {
        self
    }
}

/// Host-side literal: flat data + dims. Fully functional in the stub.
#[derive(Debug, Clone, Default)]
pub struct Literal {
    data: Vec<f32>,
    dims: Vec<i64>,
}

impl Literal {
    pub fn scalar(v: f32) -> Literal {
        Literal { data: vec![v], dims: vec![] }
    }

    pub fn vec1(data: &[f32]) -> Literal {
        Literal { data: data.to_vec(), dims: vec![data.len() as i64] }
    }

    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        let elems: i64 = dims.iter().product();
        if elems as usize != self.data.len() {
            return Err(Error::msg(format!(
                "reshape: {} elements cannot take shape {:?}",
                self.data.len(),
                dims
            )));
        }
        Ok(Literal { data: self.data.clone(), dims: dims.to_vec() })
    }

    pub fn to_vec<T: ArrayElement>(&self) -> Result<Vec<T>> {
        Ok(self.data.iter().map(|&v| T::from_f32(v)).collect())
    }

    /// Unpack a tuple literal. Stub literals are never tuples (tuples only
    /// arise from device execution, which the stub cannot perform).
    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        Err(Error::msg("stub literal is not a tuple (no device execution)"))
    }

    pub fn shape(&self) -> &[i64] {
        &self.dims
    }
}

/// Parsed HLO module handle. The stub validates the file is readable but
/// does not parse HLO text.
#[derive(Debug, Clone)]
pub struct HloModuleProto {
    pub path: String,
}

impl HloModuleProto {
    pub fn from_text_file(path: &str) -> Result<HloModuleProto> {
        std::fs::metadata(path)
            .map_err(|e| Error::msg(format!("reading HLO text {path}: {e}")))?;
        Ok(HloModuleProto { path: path.to_string() })
    }
}

/// Computation handle built from a parsed module.
#[derive(Debug, Clone)]
pub struct XlaComputation {
    pub path: String,
}

impl XlaComputation {
    pub fn from_proto(proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { path: proto.path.clone() }
    }
}

/// A device placement handle (unused by the stub; present so call sites
/// can pass `None` for the device argument with full type inference).
#[derive(Debug, Clone, Copy)]
pub struct PjRtDevice;

/// Device-resident buffer handle. Never constructed by the stub.
#[derive(Debug)]
pub struct PjRtBuffer {
    _private: (),
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(Error::unavailable())
    }
}

/// Marker for types `execute_b` can yield (mirrors xla-rs's generic
/// execution output parameter).
pub trait ExecuteOutput: Sized {}

impl ExecuteOutput for PjRtBuffer {}

/// Compiled executable handle. Never successfully constructed by the stub.
#[derive(Debug)]
pub struct PjRtLoadedExecutable {
    _private: (),
}

impl PjRtLoadedExecutable {
    pub fn execute_b<T: ExecuteOutput>(
        &self,
        _args: &[PjRtBuffer],
    ) -> Result<Vec<Vec<T>>> {
        Err(Error::unavailable())
    }
}

/// PJRT client. Construction succeeds (so manifests can be inspected and
/// `info` works); anything requiring the device errors with a clear
/// message.
#[derive(Debug, Default)]
pub struct PjRtClient {
    _private: (),
}

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Ok(PjRtClient::default())
    }

    pub fn platform_name(&self) -> String {
        "offline-stub".to_string()
    }

    pub fn compile(&self, comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(Error::msg(format!("{UNAVAILABLE_MSG}; cannot compile {}", comp.path)))
    }

    pub fn buffer_from_host_buffer<T: ArrayElement>(
        &self,
        _data: &[T],
        _dims: &[usize],
        _device: Option<&PjRtDevice>,
    ) -> Result<PjRtBuffer> {
        Err(Error::unavailable())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_scalar_and_vec_round_trip() {
        assert_eq!(Literal::scalar(2.5).to_vec::<f32>().unwrap(), vec![2.5]);
        let l = Literal::vec1(&[1.0, 2.0, 3.0, 4.0]);
        assert_eq!(l.to_vec::<f32>().unwrap().len(), 4);
    }

    #[test]
    fn reshape_checks_element_count() {
        let l = Literal::vec1(&[1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        assert!(l.reshape(&[2, 3]).is_ok());
        assert!(l.reshape(&[4, 2]).is_err());
    }

    #[test]
    fn device_paths_report_unavailable() {
        assert!(!backend_available());
        let client = PjRtClient::cpu().unwrap();
        assert_eq!(client.platform_name(), "offline-stub");
        let err = client
            .buffer_from_host_buffer::<f32>(&[0.0], &[1], None)
            .unwrap_err();
        assert!(err.to_string().contains("unavailable"));
    }
}
