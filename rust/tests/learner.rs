//! Actor-learner golden suite: the determinism contract of DESIGN.md §11.
//!
//! * `learner=pinned` — the dedicated learner thread replaying the exact
//!   inline update schedule — is **bit-identical** to `learner=inline`:
//!   episode logs, Pareto frontiers, replay contents, update counters;
//!   per required seeds {7, 42} at 7nm and 28nm, across wave boundaries,
//!   for any worker count, and under a deliberately tiny queue bound
//!   (backpressure never drops or reorders).
//! * `learner=async` with the warmup gate shut absorbs exactly the
//!   inline replay stream (the queue's no-drop/no-reorder property,
//!   end-to-end), and free-runs past warmup to a converging smoke.
//!
//! Queue/snapshot unit tests (FIFO, backpressure, high-water, version
//! monotonicity) live in `rl::learner`'s own `#[cfg(test)]` module.

use silicon_rl::config::{Granularity, RunConfig};
use silicon_rl::env::{ACT_DIM, SAC_STATE_DIM};
use silicon_rl::nn::backend::{self, BackendSel};
use silicon_rl::rl::{self, LaneSpec, NodeResult, SacAgent};
use silicon_rl::util::Rng;

/// The acceptance lanes: required seeds {7, 42} at 7nm and 28nm.
const SPECS: [LaneSpec; 4] = [
    LaneSpec { nm: 7, seed: 7 },
    LaneSpec { nm: 7, seed: 42 },
    LaneSpec { nm: 28, seed: 7 },
    LaneSpec { nm: 28, seed: 42 },
];

/// Live-update config: warmup 8 → the effective gate is max(8,
/// minibatch=256), so with 4 lanes the buffer crosses 256 at step 63 and
/// the last steps run live SAC + wm + sur updates (and, once the world
/// model trains, the MPC planner with real re-ranking).
fn live_cfg(episodes: usize) -> RunConfig {
    let mut cfg = RunConfig::default();
    cfg.backend = BackendSel::Native;
    cfg.artifacts_dir = "/nonexistent-artifacts".into();
    cfg.granularity = Granularity::Group;
    cfg.rl.episodes_per_node = episodes;
    cfg.rl.warmup_steps = 8;
    cfg
}

/// Fresh agent with the pinned seed-42 store init (the same init every
/// reference run uses, so shared-store reads are identical).
fn fresh_agent(cfg: &RunConfig) -> SacAgent {
    let be = backend::load(&cfg.artifacts_dir, cfg.backend).unwrap();
    SacAgent::new(be, cfg.rl, &mut Rng::new(42)).unwrap()
}

fn run(cfg: &RunConfig, lanes: usize, threads: usize) -> (Vec<NodeResult>, SacAgent, Option<rl::LearnerReport>) {
    let mut agent = fresh_agent(cfg);
    let (results, report) =
        rl::run_jobs_stats(cfg, &SPECS, lanes, &mut agent, threads).unwrap();
    (results, agent, report)
}

fn assert_logs_identical(a: &NodeResult, b: &NodeResult, what: &str) {
    assert_eq!(a.episodes.len(), b.episodes.len(), "{what}: episode count");
    for (x, y) in a.episodes.iter().zip(&b.episodes) {
        let ep = x.episode;
        assert_eq!(x.reward.to_bits(), y.reward.to_bits(), "{what} ep {ep}: reward");
        assert_eq!(x.score.to_bits(), y.score.to_bits(), "{what} ep {ep}: score");
        assert_eq!(
            x.best_score.to_bits(),
            y.best_score.to_bits(),
            "{what} ep {ep}: best_score"
        );
        assert_eq!(x.feasible, y.feasible, "{what} ep {ep}: feasible");
        assert_eq!(x.eps.to_bits(), y.eps.to_bits(), "{what} ep {ep}: eps");
        assert_eq!(x.entropy.to_bits(), y.entropy.to_bits(), "{what} ep {ep}: entropy");
        assert_eq!((x.mesh_w, x.mesh_h), (y.mesh_w, y.mesh_h), "{what} ep {ep}: mesh");
        assert_eq!(x.unique_configs, y.unique_configs, "{what} ep {ep}: unique");
    }
    assert_eq!(a.feasible_count, b.feasible_count, "{what}: feasible_count");
}

fn assert_frontiers_identical(a: &NodeResult, b: &NodeResult, what: &str) {
    let (fa, fb) = (a.pareto.frontier(), b.pareto.frontier());
    assert_eq!(fa.len(), fb.len(), "{what}: frontier size");
    for (p, q) in fa.iter().zip(fb) {
        assert_eq!(p.perf_gops.to_bits(), q.perf_gops.to_bits(), "{what}: perf");
        assert_eq!(p.power_mw.to_bits(), q.power_mw.to_bits(), "{what}: power");
        assert_eq!(p.area_mm2.to_bits(), q.area_mm2.to_bits(), "{what}: area");
        assert_eq!(p.episode, q.episode, "{what}: episode tag");
    }
}

/// Replay buffers bit-identical slot for slot — the strongest
/// no-drop/no-reorder statement available end-to-end.
fn assert_buffers_identical(a: &SacAgent, b: &SacAgent, what: &str) {
    assert_eq!(a.buffer.len(), b.buffer.len(), "{what}: buffer length");
    for t in 0..a.buffer.len() {
        let (x, y) = (a.buffer.get(t), b.buffer.get(t));
        assert_eq!(x.r.to_bits(), y.r.to_bits(), "{what} slot {t}: reward");
        assert_eq!(x.done.to_bits(), y.done.to_bits(), "{what} slot {t}: done");
        for j in 0..SAC_STATE_DIM {
            assert_eq!(x.s[j].to_bits(), y.s[j].to_bits(), "{what} slot {t}: s[{j}]");
            assert_eq!(x.s2[j].to_bits(), y.s2[j].to_bits(), "{what} slot {t}: s2[{j}]");
        }
        for j in 0..ACT_DIM {
            assert_eq!(
                x.a_cont[j].to_bits(),
                y.a_cont[j].to_bits(),
                "{what} slot {t}: a[{j}]"
            );
        }
        assert_eq!(x.a_disc, y.a_disc, "{what} slot {t}: a_disc");
        for j in 0..3 {
            assert_eq!(x.ppa[j].to_bits(), y.ppa[j].to_bits(), "{what} slot {t}: ppa[{j}]");
        }
    }
}

fn assert_runs_identical(
    inline: &(Vec<NodeResult>, SacAgent, Option<rl::LearnerReport>),
    pinned: &(Vec<NodeResult>, SacAgent, Option<rl::LearnerReport>),
    what: &str,
) {
    for (lane, (a, b)) in inline.0.iter().zip(&pinned.0).enumerate() {
        assert_logs_identical(b, a, &format!("{what} lane {lane}"));
        assert_frontiers_identical(b, a, &format!("{what} lane {lane}"));
    }
    assert_buffers_identical(&pinned.1, &inline.1, what);
    assert_eq!(
        pinned.1.updates_done, inline.1.updates_done,
        "{what}: update count diverged"
    );
    assert_eq!(pinned.1.wm_trained, inline.1.wm_trained, "{what}: wm_trained");
    assert_eq!(pinned.1.sur_trained, inline.1.sur_trained, "{what}: sur_trained");
}

/// The core contract: `learner=pinned` live runs are bit-identical to
/// `learner=inline` — episode logs, frontiers, replay contents and
/// update counters — for serial and parallel rollout workers alike.
#[test]
fn pinned_live_run_bit_identical_to_inline() {
    let cfg = live_cfg(66);
    let inline_run = run(&cfg, SPECS.len(), 1);
    assert!(inline_run.1.updates_done > 0, "updates never fired");
    assert!(inline_run.2.is_none(), "inline runs carry no learner report");

    let mut pcfg = cfg.clone();
    pcfg.apply("learner", "pinned").unwrap();
    for threads in [1usize, 4] {
        let pinned = run(&pcfg, SPECS.len(), threads);
        assert_runs_identical(&inline_run, &pinned, &format!("pinned threads={threads}"));
        let rep = pinned.2.expect("off-loop learner always reports");
        assert_eq!(rep.steps, 66, "one learner message per lockstep step");
        assert_eq!(rep.sac_updates as usize, inline_run.1.updates_done);
        assert_eq!(
            rep.snapshots, rep.sac_updates,
            "pinned publishes exactly one snapshot per update tick"
        );
        assert!(rep.queue_highwater >= SPECS.len(), "at least one batch queued");
    }
}

/// Same contract across wave boundaries: lanes=2 over the 4 jobs means
/// the learner thread, its replay buffer, the update stream and the ack
/// counter all span two waves — exactly like the inline update RNG.
#[test]
fn pinned_identity_holds_across_waves() {
    let cfg = live_cfg(66);
    let inline_run = run(&cfg, 2, 2);
    assert!(inline_run.1.updates_done > 0, "updates never fired");

    let mut pcfg = cfg.clone();
    pcfg.apply("learner", "pinned").unwrap();
    let pinned = run(&pcfg, 2, 2);
    assert_runs_identical(&inline_run, &pinned, "pinned waves of 2");
    // two waves of 66 steps each went through the one queue
    assert_eq!(pinned.2.unwrap().steps, 132);
}

/// A deliberately tiny queue bound exercises producer backpressure on
/// every step — and changes nothing: backpressure blocks, it never
/// drops or reorders.
#[test]
fn pinned_identity_survives_tiny_queue_backpressure() {
    let cfg = live_cfg(66);
    let inline_run = run(&cfg, SPECS.len(), 2);

    let mut pcfg = cfg.clone();
    pcfg.apply("learner", "pinned").unwrap();
    pcfg.apply("queue_cap", "4").unwrap(); // exactly one 4-lane batch
    let pinned = run(&pcfg, SPECS.len(), 2);
    assert_runs_identical(&inline_run, &pinned, "pinned queue_cap=4");
    assert!(pinned.2.unwrap().queue_highwater <= 4, "bound respected");
}

/// With the warmup gate shut the async learner is a pure replay sink:
/// the restored buffer must be the exact lane-major inline stream —
/// the queue's no-drop/no-reorder property proven end-to-end, without
/// the pinned mode's step synchronization.
#[test]
fn async_rollout_only_replay_is_bit_identical() {
    let mut cfg = live_cfg(40);
    cfg.rl.warmup_steps = 10_000; // gate never opens
    let inline_run = run(&cfg, SPECS.len(), 2);

    let mut acfg = cfg.clone();
    acfg.apply("learner", "async").unwrap();
    let async_run = run(&acfg, SPECS.len(), 2);
    // rollout streams never see an update in either mode → logs identical
    assert_runs_identical(&inline_run, &async_run, "async rollout-only");
    let rep = async_run.2.unwrap();
    assert_eq!(rep.steps, 40);
    assert_eq!(rep.sac_updates, 0, "warmup gate stayed closed");
    assert_eq!(rep.snapshots, 0);
    assert_eq!(rep.mean_lanes_behind, 0.0, "nothing published to lag behind");
}

/// Free-running async smoke: updates fire past warmup, snapshots get
/// published and adopted, and the run completes with finite results.
/// (Seed-reproducibility is explicitly NOT claimed here — snapshot
/// pickup depends on thread timing.)
#[test]
fn async_free_run_converges_past_warmup() {
    let mut cfg = live_cfg(70);
    cfg.apply("learner", "async").unwrap();
    // capped budget: one update round per post-warmup step, leftovers
    // drained after the rollout closes the queue
    cfg.apply("updates_per_step", "1").unwrap();
    let (results, agent, report) = run(&cfg, SPECS.len(), 2);
    let rep = report.unwrap();
    assert_eq!(rep.steps, 70);
    assert!(rep.sac_updates > 0, "no updates past warmup");
    assert!(rep.snapshots >= 1, "no snapshots published");
    assert_eq!(rep.snapshots, rep.sac_updates);
    assert!(agent.updates_done > 0, "learner state not folded back");
    assert_eq!(agent.buffer.len(), 70 * SPECS.len());
    for r in &results {
        assert_eq!(r.episodes.len(), 70);
        assert!(r.episodes.iter().all(|e| e.reward.is_finite()));
    }

    // uncapped free-run: the update count is timing-dependent (that's
    // the point of free-running), so assert structure, not counters —
    // every step absorbed, replay restored intact, run completes
    let mut ucfg = cfg.clone();
    ucfg.apply("updates_per_step", "0").unwrap();
    let (uresults, uagent, ureport) = run(&ucfg, SPECS.len(), 2);
    let urep = ureport.unwrap();
    assert_eq!(urep.steps, 70);
    assert_eq!(urep.snapshots, urep.sac_updates);
    assert_eq!(uagent.buffer.len(), 70 * SPECS.len());
    assert!(uresults.iter().all(|r| r.episodes.len() == 70));
}
