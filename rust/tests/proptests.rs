//! Property-based tests over coordinator invariants. The image vendors no
//! proptest crate, so properties are swept with the crate's deterministic
//! RNG (util::Rng) over a few hundred random cases each — same idea:
//! random inputs, universal assertions, reproducible failures (the seed is
//! printed on panic via assert messages).

use silicon_rl::arch::{derive_tiles, MeshConfig, ParamRanges, TccParams, TileLoad};
use silicon_rl::arch::ranges::{QuantPolicy, Quantizer};
use silicon_rl::config::{Granularity, RunConfig};
use silicon_rl::env::{Action, Env, ACT_DIM, N_DISC, SAC_STATE_DIM};
use silicon_rl::eval::{EvalCache, EvalScratch, Evaluator};
use silicon_rl::hazard::Mitigation;
use silicon_rl::ir::{llama, PartitionClass};
use silicon_rl::partition::{self, PartitionKnobs, Unit};
use silicon_rl::ppa::PpaWeights;
use silicon_rl::rl::{ParetoArchive, ParetoPoint, PerBuffer, Transition};
use silicon_rl::util::{stats, Rng};

fn random_units(rng: &mut Rng, n: usize) -> Vec<Unit> {
    (0..n)
        .map(|i| {
            let class = match rng.below(3) {
                0 => PartitionClass::MatMul,
                1 => PartitionClass::Conv,
                _ => PartitionClass::General,
            };
            let kind = match class {
                PartitionClass::MatMul => silicon_rl::ir::OpKind::MatMul,
                PartitionClass::Conv => silicon_rl::ir::OpKind::Conv,
                PartitionClass::General => silicon_rl::ir::OpKind::Softmax,
            };
            Unit {
                class,
                flops: rng.uniform_in(0.0, 1e9),
                weight_bytes: rng.uniform_in(0.0, 5e7),
                out_bytes: rng.uniform_in(64.0, 1e6),
                instrs: rng.uniform_in(10.0, 1e5),
                inputs: if i > 0 { vec![rng.below(i) as u32] } else { vec![] },
                kind,
            }
        })
        .collect()
}

fn random_knobs(rng: &mut Rng) -> PartitionKnobs {
    PartitionKnobs {
        rho_base: rng.uniform_in(0.0, 1.0),
        d_matmul: rng.uniform_in(-0.5, 0.7),
        d_conv: rng.uniform_in(-0.5, 0.7),
        d_general: rng.uniform_in(-0.5, 0.5),
        w_load: rng.uniform_in(0.1, 3.0),
        streaming_in: rng.uniform_in(0.0, 1.0),
        streaming_out: rng.uniform_in(0.0, 1.0),
        sub_matmul: rng.uniform_in(0.0, 2.0),
        allreduce_frac: rng.uniform_in(0.0, 1.0),
    }
}

#[test]
fn prop_placement_conserves_flops_and_weights() {
    let mut rng = Rng::new(0xA11 + 1);
    let mit = Mitigation { stanum: 4, fetch: 4, xr_wp: 2, vr_wp: 2 };
    for case in 0..60 {
        let n_units = 32 + rng.below(100);
        let units = random_units(&mut rng, n_units);
        let mesh = MeshConfig::new(2 + rng.below(14) as u32, 2 + rng.below(14) as u32);
        let knobs = random_knobs(&mut rng);
        let p = partition::place_units(&units, &mesh, &knobs, &mit);
        let uf: f64 = units.iter().map(|u| u.flops).sum();
        let pf: f64 = p.loads.iter().map(|l| l.flops).sum();
        assert!((uf - pf).abs() <= 1e-6 * uf.max(1.0), "case {case}: flops leak");
        let uw: f64 = units.iter().map(|u| u.weight_bytes).sum();
        let pw: f64 = p.loads.iter().map(|l| l.weight_bytes).sum();
        assert!((uw - pw).abs() <= 1e-6 * uw.max(1.0), "case {case}: weight leak");
        // balance score in (0, 1]
        assert!(p.load_stats.balance > 0.0 && p.load_stats.balance <= 1.0);
        // traffic statistics self-consistent
        assert!(p.traffic.byte_hops >= p.traffic.cross_tile_bytes - 1e-9);
        assert!(p.traffic.bisection_bytes <= p.traffic.cross_tile_bytes + 1e-9);
    }
}

#[test]
fn prop_quantizers_respect_bounds_and_policy() {
    let mut rng = Rng::new(2);
    for _ in 0..300 {
        let lo = 2f64.powi(rng.below(6) as i32);
        let hi = lo * 2f64.powi(1 + rng.below(8) as i32);
        let q = Quantizer::new(lo, hi, QuantPolicy::PowerOfTwo);
        let v = rng.uniform_in(0.0, hi * 2.0);
        let out = q.quantize(v) as f64;
        let up = q.quantize_up(v) as f64;
        for o in [out, up] {
            assert!(o >= lo && o <= hi, "{o} outside [{lo},{hi}]");
            assert!((o as u32).is_power_of_two());
        }
        // quantize_up never loses capacity (within bounds)
        if v >= lo && v <= hi {
            assert!(up >= v - 1e-9, "up {up} < v {v}");
        }
        assert!(up >= out || (v > hi));
    }
}

#[test]
fn prop_hetero_tiles_always_within_table7() {
    let mut rng = Rng::new(3);
    let ranges = ParamRanges::paper();
    for _ in 0..40 {
        let mesh = MeshConfig::new(2 + rng.below(10) as u32, 2 + rng.below(10) as u32);
        let mut avg = TccParams::default_for(rng.uniform_in(10.0, 1000.0));
        avg.vlen_bits = ranges.vlen_bits.from_unit(rng.uniform_in(-1.0, 1.0));
        avg.dmem_kb = ranges.dmem_kb.from_unit(rng.uniform_in(-1.0, 1.0));
        let loads: Vec<TileLoad> = (0..mesh.cores())
            .map(|_| TileLoad {
                flops: rng.uniform_in(0.0, 1e10),
                weight_bytes: rng.uniform_in(0.0, 2e8),
                act_bytes: rng.uniform_in(0.0, 2e6),
                kv_bytes: rng.uniform_in(0.0, 1e6),
                instrs: rng.uniform_in(1.0, 1e6),
                hazard_density: rng.uniform_in(0.0, 1.0),
            })
            .collect();
        let tiles = derive_tiles(&mesh, &avg, &loads, &ranges);
        for t in &tiles {
            assert!((1..=16).contains(&t.fetch) && t.fetch.is_power_of_two());
            assert!((128..=2048).contains(&t.vlen_bits));
            assert!(t.vlen_bits.is_power_of_two());
            assert!((16..=1024).contains(&t.dmem_kb));
            assert!((1..=128).contains(&t.imem_kb));
            assert!(t.wmem_kb >= 256);
            // capacity covers placement unless capped at the range max
            let cap = t.wmem_kb as f64 * 1024.0;
            let used = loads[t.tile].weight_bytes;
            assert!(cap >= used || t.wmem_kb == 131_072, "tile {}", t.tile);
        }
    }
}

#[test]
fn prop_env_eval_never_panics_and_stays_finite() {
    let mut cfg = RunConfig::default();
    cfg.granularity = Granularity::Group;
    let mut rng = Rng::new(4);
    for nm in [3u32, 10, 28] {
        let mut env = Env::new(&cfg, nm);
        for _ in 0..15 {
            let mut a = Action::neutral();
            for v in a.cont.iter_mut() {
                *v = rng.uniform_in(-1.5, 1.5); // deliberately out of range
            }
            for d in a.deltas.iter_mut() {
                *d = rng.below(5) as i32 - 2;
            }
            let out = env.eval_action(&a);
            assert!(out.ppa.tokens_per_s.is_finite());
            assert!(out.ppa.power.total() > 0.0);
            assert!(out.ppa.area.total() > 0.0);
            assert!(out.reward.total.is_finite());
            assert!(out.full_state.iter().all(|v| v.is_finite()));
            assert!(out.reward.score >= 0.0 && out.reward.score <= 1.0 + 1e-9);
        }
    }
}

#[test]
fn prop_pareto_archive_invariants_under_random_inserts() {
    let mut rng = Rng::new(5);
    let mut archive = ParetoArchive::new();
    for i in 0..500 {
        archive.insert(ParetoPoint {
            perf_gops: rng.uniform_in(1.0, 1e6),
            power_mw: rng.uniform_in(1.0, 1e5),
            area_mm2: rng.uniform_in(1.0, 4e3),
            tokens_per_s: rng.uniform_in(1.0, 3e4),
            episode: i,
            tag: i,
        });
        // no point on the frontier dominates another
        let f = archive.frontier();
        for a in f {
            for b in f {
                assert!(!a.dominates(b) || std::ptr::eq(a, b));
            }
        }
    }
    // selection always returns a frontier member for any weights
    for _ in 0..20 {
        let w = PpaWeights {
            perf: rng.uniform_in(0.01, 1.0),
            power: rng.uniform_in(0.01, 1.0),
            area: rng.uniform_in(0.01, 1.0),
        };
        let sel = archive.select(&w).unwrap();
        assert!(archive.frontier().iter().any(|p| p.tag == sel.tag));
    }
}

#[test]
fn prop_action_decode_total_dims_match_paper() {
    assert_eq!(ACT_DIM, 30);
    assert_eq!(N_DISC, 4);
}

#[test]
fn prop_stats_summary_consistency() {
    let mut rng = Rng::new(6);
    for _ in 0..100 {
        let n = 1 + rng.below(200);
        let xs: Vec<f64> = (0..n).map(|_| rng.uniform_in(-100.0, 100.0)).collect();
        let s = stats::summary(&xs);
        assert!(s.min <= s.median && s.median <= s.max);
        assert!(s.min <= s.mean && s.mean <= s.max);
        assert!(s.std_dev >= 0.0);
        assert!(s.unique >= 1 && s.unique <= n);
        let g = stats::gini(&xs.iter().map(|x| x.abs()).collect::<Vec<_>>());
        assert!((0.0..=1.0).contains(&g));
    }
}

fn marker_transition(r: f32) -> Transition {
    Transition {
        s: [r; SAC_STATE_DIM],
        a_cont: [0.0; ACT_DIM],
        a_disc: [0.0; 20],
        r,
        s2: [0.0; SAC_STATE_DIM],
        done: 0.0,
        ppa: [0.0; 3],
    }
}

/// PER invariants under interleaved batched (lane-major) inserts,
/// priority refreshes and stratified samples — the vec-env access
/// pattern: the sum-tree root always equals the leaf priority sum,
/// priorities stay positive, sampled indices stay in range with
/// normalized weights, the ring never exceeds capacity, and the whole
/// op sequence is deterministic from the RNG seed.
#[test]
fn prop_per_invariants_under_interleaved_batch_insert_and_sample() {
    for case in 0..8u64 {
        let mut rng = Rng::new(0xBEEF + case);
        let cap = 24 + rng.below(48);
        let mut b = PerBuffer::new(cap, 0.6, 0.4, 0.0005);
        // shadow receives the identical op sequence: identical trees must
        // sample identically under identically-seeded RNGs
        let mut shadow = PerBuffer::new(cap, 0.6, 0.4, 0.0005);
        let mut pushed = 0usize;
        for op in 0..80 {
            match rng.below(3) {
                0 => {
                    // batched lane-major insert (possibly wrapping)
                    let lanes = 1 + rng.below(6);
                    b.push_batch((0..lanes).map(|l| {
                        marker_transition((pushed + l) as f32)
                    }));
                    shadow.push_batch(
                        (0..lanes).map(|l| marker_transition((pushed + l) as f32)),
                    );
                    pushed += lanes;
                }
                1 if !b.is_empty() => {
                    let k = 1 + rng.below(6);
                    let idxs: Vec<usize> =
                        (0..k).map(|_| rng.below(b.len())).collect();
                    let tds: Vec<f32> = (0..k)
                        .map(|_| rng.uniform_in(0.0, 8.0) as f32)
                        .collect();
                    b.update_priorities(&idxs, &tds);
                    shadow.update_priorities(&idxs, &tds);
                }
                _ if !b.is_empty() => {
                    let mut sample_rng = Rng::new(case * 1000 + op);
                    let (ix, w) = b.sample(8, &mut sample_rng);
                    assert!(ix.iter().all(|&i| i < b.len()), "case {case} op {op}");
                    assert!(w.iter().all(|&x| x > 0.0 && x <= 1.0 + 1e-6));
                    assert!(w.iter().any(|&x| (x - 1.0).abs() < 1e-6));
                    // deterministic given the RNG seed and op history
                    let mut replay_rng = Rng::new(case * 1000 + op);
                    let (ix2, _) = shadow.sample(8, &mut replay_rng);
                    assert_eq!(ix, ix2, "case {case} op {op}: sample diverged");
                }
                _ => {}
            }
            // root == Σ leaves after every op, and the ring is bounded
            let leaf_sum: f64 = (0..b.len()).map(|i| b.priority(i)).sum();
            let total = b.priority_total();
            assert!(
                (total - leaf_sum).abs() <= 1e-9 * leaf_sum.max(1.0),
                "case {case} op {op}: root {total} != leaf sum {leaf_sum}"
            );
            assert!(b.len() <= b.capacity());
            assert!((0..b.len()).all(|i| b.priority(i) > 0.0));
        }
        assert!(b.len() == pushed.min(cap));
    }
}

/// Ordering invariant of the stratified sampler: mass overwhelmingly on
/// one leaf pulls most stratified draws to it, even after batched
/// inserts wrapped the ring.
#[test]
fn prop_per_sampling_tracks_priority_mass_after_wraparound() {
    let mut b = PerBuffer::new(32, 0.6, 0.4, 0.0);
    // 48 inserts into capacity 32: the ring wrapped
    b.push_batch((0..48).map(|i| marker_transition(i as f32)));
    assert_eq!(b.len(), 32);
    let idxs: Vec<usize> = (0..32).collect();
    let mut tds = vec![0.01f32; 32];
    tds[11] = 500.0;
    b.update_priorities(&idxs, &tds);
    let mut rng = Rng::new(9);
    let mut hits = 0;
    for _ in 0..40 {
        let (ix, _) = b.sample(16, &mut rng);
        hits += ix.iter().filter(|&&i| i == 11).count();
    }
    assert!(hits > 300, "dominant leaf sampled only {hits}/640");
}

/// Vec-env cache safety: lanes at different nodes and scenario points
/// share raw `(mesh, action)` fingerprints, but a shared outcome memo
/// must never replay across them — every cached result equals a fresh
/// uncached evaluation bitwise, and same-lane repeats do hit.
#[test]
fn prop_shared_eval_cache_is_scenario_safe_across_lanes() {
    let mk = |nm: u32, prefill: bool, seq: Option<u32>| {
        let mut c = RunConfig::default();
        c.granularity = Granularity::Group;
        if prefill {
            c.phase = silicon_rl::ir::Phase::Prefill;
        }
        c.seq_len = seq;
        Evaluator::new(&c, nm)
    };
    // three "lanes": same workload, different node / phase / context
    let evs = [mk(3, false, None), mk(3, true, None), mk(28, false, Some(4096))];
    assert!(evs.iter().enumerate().all(|(i, a)| {
        evs.iter().skip(i + 1).all(|b| a.eval_salt() != b.eval_salt())
    }));

    let mut rng = Rng::new(0xCAFE);
    let pool: Vec<Action> = (0..4)
        .map(|_| {
            let mut a = Action::neutral();
            for v in a.cont.iter_mut() {
                *v = rng.uniform_in(-1.0, 1.0);
            }
            for d in a.deltas.iter_mut() {
                *d = rng.below(5) as i32 - 2;
            }
            a
        })
        .collect();

    let mut cache = EvalCache::new(64);
    let mut scratch = EvalScratch::default();
    for round in 0..36 {
        let ev = &evs[rng.below(evs.len())];
        let a = &pool[rng.below(pool.len())];
        let mesh = ev.initial_mesh();
        let cached = cache.evaluate(ev, &mesh, a, &mut scratch);
        let fresh = ev.evaluate(&mesh, a, &mut EvalScratch::default());
        assert_eq!(
            cached.reward.total.to_bits(),
            fresh.reward.total.to_bits(),
            "round {round}: cached reward != fresh"
        );
        assert_eq!(
            cached.reward.score.to_bits(),
            fresh.reward.score.to_bits(),
            "round {round}: cached score != fresh"
        );
        assert_eq!(
            cached.ppa.tokens_per_s.to_bits(),
            fresh.ppa.tokens_per_s.to_bits(),
            "round {round}: cached throughput != fresh"
        );
        assert_eq!(cached.decoded.mesh, fresh.decoded.mesh, "round {round}");
    }
    // the pool is small: same-lane repeats must have hit, and misses are
    // bounded by |lanes| × |pool| distinct salted keys
    assert!(cache.hits > 0, "no cache hits across 36 rounds");
    assert!(cache.misses <= (evs.len() * pool.len()) as u64);
}

#[test]
fn prop_llama_placement_compute_bound_for_reasonable_knobs() {
    // Eq 24 shape: for sane knob settings the compute ceiling binds
    let g = llama::build();
    let units = partition::groups::units_from_groups(&g);
    let mit = Mitigation { stanum: 8, fetch: 4, xr_wp: 2, vr_wp: 2 };
    let mut rng = Rng::new(7);
    for _ in 0..10 {
        let mut knobs = random_knobs(&mut rng);
        knobs.streaming_in = rng.uniform_in(0.4, 1.0);
        let mesh = MeshConfig::new(8 + rng.below(30) as u32, 8 + rng.below(30) as u32);
        let p = partition::place_units(&units, &mesh, &knobs, &mit);
        // all weights placed; eta_par sane
        assert!(p.eta_parallel() > 0.05 && p.eta_parallel() <= 1.0);
    }
}
