//! End-to-end tests over the AOT artifacts: PJRT load/compile/execute of
//! every entrypoint, SAC update mechanics, world-model/MPC path, and a
//! short Algorithm 1 run. Skipped (pass trivially) when `make artifacts`
//! has not been run.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use silicon_rl::config::{Granularity, RunConfig};
use silicon_rl::env::{ACT_DIM, SAC_STATE_DIM};
use silicon_rl::nn::{backend, Store};
use silicon_rl::rl::{run_node, SacAgent, Transition};
use silicon_rl::runtime::{self, Runtime};
use silicon_rl::util::Rng;

/// Artifact gate: these tests need both the AOT artifacts (`make
/// artifacts`) and a real PJRT backend. On a fresh checkout — or an
/// offline build using the xla stub — they skip with a clear message
/// instead of failing.
fn artifacts_dir() -> Option<PathBuf> {
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !dir.join("manifest.json").exists() {
        eprintln!("artifacts not built (run `make artifacts`); skipping runtime e2e test");
        return None;
    }
    if !runtime::backend_available() {
        eprintln!("PJRT backend unavailable (offline xla stub); skipping runtime e2e test");
        return None;
    }
    Some(dir)
}

fn agent(seed: u64) -> Option<(SacAgent, Rng)> {
    let dir = artifacts_dir()?;
    let runtime = Runtime::load(&dir).expect("runtime loads");
    let mut rng = Rng::new(seed);
    let cfg = RunConfig::default().rl;
    let agent = SacAgent::new(backend::pjrt(runtime), cfg, &mut rng).expect("agent init");
    Some((agent, rng))
}

#[test]
fn actor_forward_produces_valid_heads() {
    let Some((mut agent, mut rng)) = agent(1) else { return };
    let s = [0.25f32; SAC_STATE_DIM];
    let a = agent.act(&s, true, &mut rng).expect("act");
    assert!(a.cont.iter().all(|v| v.abs() <= 1.0));
    assert!(a.deltas.iter().all(|d| (-2..=2).contains(d)));
    // entropy trace populated (Fig 3)
    assert!(agent.last_entropy.is_finite());
    // deterministic head differs from stochastic in general
    let det = agent.act(&s, false, &mut rng).expect("act det");
    let det2 = agent.act(&s, false, &mut rng).expect("act det2");
    assert_eq!(det.cont, det2.cont, "deterministic head must be stable");
}

fn synthetic_transition(rng: &mut Rng, reward: f32) -> Transition {
    let mut t = Transition {
        s: [0.0; SAC_STATE_DIM],
        a_cont: [0.0; ACT_DIM],
        a_disc: [0.0; 20],
        r: reward,
        s2: [0.0; SAC_STATE_DIM],
        done: 0.0,
        ppa: [0.3, 0.5, 0.2],
    };
    for v in t.s.iter_mut().chain(t.s2.iter_mut()) {
        *v = rng.uniform() as f32;
    }
    for v in t.a_cont.iter_mut() {
        *v = rng.uniform_in(-0.99, 0.99) as f32;
    }
    for d in 0..4 {
        t.a_disc[d * 5 + rng.below(5)] = 1.0;
    }
    t
}

#[test]
fn sac_update_moves_parameters_and_returns_priorities() {
    let Some((mut agent, mut rng)) = agent(2) else { return };
    for i in 0..300 {
        let tr = synthetic_transition(&mut rng, (i % 7) as f32 * 0.1);
        agent.push_transition(tr);
    }
    let w_before = agent.store.get("actor/W1").unwrap().to_vec();
    let t_before = agent.store.get("t1/Wa").unwrap().to_vec();
    let q_before = agent.store.get("c1/Wa").unwrap().to_vec();
    let m = agent.update(&mut rng).expect("sac update");
    assert!(m.critic_loss.is_finite() && m.actor_loss.is_finite());
    assert!(m.alpha > 0.0);
    let w_after = agent.store.get("actor/W1").unwrap();
    assert!(w_before.iter().zip(w_after).any(|(a, b)| a != b), "actor unchanged");
    // Polyak targets move much less than the online critic (tau=0.005)
    let dq: f32 = agent
        .store
        .get("c1/Wa")
        .unwrap()
        .iter()
        .zip(&q_before)
        .map(|(a, b)| (a - b).abs())
        .fold(0.0, f32::max);
    let dt: f32 = agent
        .store
        .get("t1/Wa")
        .unwrap()
        .iter()
        .zip(&t_before)
        .map(|(a, b)| (a - b).abs())
        .fold(0.0, f32::max);
    assert!(dq > 0.0 && dt > 0.0 && dt < dq, "dq {dq} dt {dt}");
    // step counter advanced inside the HLO
    assert_eq!(agent.store.get("step").unwrap()[0], 1.0);
}

#[test]
fn world_model_and_surrogate_losses_decrease() {
    let Some((mut agent, mut rng)) = agent(3) else { return };
    for _ in 0..300 {
        let tr = synthetic_transition(&mut rng, 0.5);
        agent.push_transition(tr);
    }
    let mut wm_losses = Vec::new();
    let mut sur_losses = Vec::new();
    for _ in 0..25 {
        wm_losses.push(agent.train_world_model(&mut rng).unwrap());
        sur_losses.push(agent.train_surrogate(&mut rng).unwrap());
    }
    assert!(
        wm_losses.last().unwrap() < wm_losses.first().unwrap(),
        "wm {wm_losses:?}"
    );
    assert!(
        sur_losses.last().unwrap() < sur_losses.first().unwrap(),
        "sur {sur_losses:?}"
    );
}

#[test]
fn mpc_refine_blends_tcc_dims_only() {
    let Some((mut agent, mut rng)) = agent(4) else { return };
    for _ in 0..300 {
        let tr = synthetic_transition(&mut rng, 0.1);
        agent.push_transition(tr);
    }
    agent.train_world_model(&mut rng).unwrap();
    let s = [0.4f32; SAC_STATE_DIM];
    let base = agent.act(&s, false, &mut rng).unwrap();
    let refined = agent.mpc_refine(&s, &base, None, &mut rng).unwrap();
    // discrete deltas untouched
    assert_eq!(refined.deltas, base.deltas);
    // non-TCC continuous dims (15..30) untouched
    for i in 15..ACT_DIM {
        assert_eq!(refined.cont[i], base.cont[i], "dim {i}");
    }
    // some TCC dim moved (noise std 0.3 makes a no-op vanishingly rare)
    assert!(
        (0..15).any(|i| refined.cont[i] != base.cont[i]),
        "MPC refinement was a no-op"
    );
}

#[test]
fn short_algorithm1_run_completes() {
    let Some(dir) = artifacts_dir() else { return };
    let runtime = Runtime::load(&dir).unwrap();
    let mut cfg = RunConfig::default();
    cfg.granularity = Granularity::Group;
    cfg.rl.episodes_per_node = 25;
    cfg.rl.warmup_steps = 10_000; // skip updates: keep the test fast
    let mut rng = Rng::new(5);
    let mut agent = SacAgent::new(backend::pjrt(runtime), cfg.rl, &mut rng).unwrap();
    let r = run_node(&cfg, 3, &mut agent, &mut rng).expect("run_node");
    assert_eq!(r.episodes.len(), 25);
    assert!(r.feasible_count > 0, "no feasible configs in 25 episodes");
    assert!(r.best.is_some());
    // epsilon decayed
    assert!(r.episodes.last().unwrap().eps < cfg.rl.eps0);
    // unique-config trace is monotone (Fig 3)
    for w in r.episodes.windows(2) {
        assert!(w[1].unique_configs >= w[0].unique_configs);
    }
}

#[test]
fn store_matches_manifest_and_hyper() {
    let Some(dir) = artifacts_dir() else { return };
    let runtime = Runtime::load(&dir).unwrap();
    assert_eq!(runtime.manifest.hyper_or("state_dim", 0.0) as usize, SAC_STATE_DIM);
    assert_eq!(runtime.manifest.hyper_or("act_dim", 0.0) as usize, ACT_DIM);
    let mut rng = Rng::new(6);
    let store = Store::from_manifest(&runtime.manifest, &mut rng).unwrap();
    // every sac_update state input resolvable
    let batch = BTreeMap::new();
    let mut resolver = store.resolver(&batch);
    for spec in &runtime.manifest.entrypoints["sac_update"].inputs {
        if spec.name.starts_with("state/") {
            assert!(resolver(&spec.name).is_some(), "{} unresolvable", spec.name);
        }
    }
}
