//! Cross-module integration tests that do NOT require the AOT artifacts:
//! baselines over the full evaluation pipeline, report generation, design
//! artifact emission, and end-to-end determinism.

use silicon_rl::config::{Granularity, RunConfig, Workload};
use silicon_rl::env::{Action, Env};
use silicon_rl::ppa::throughput::Binding;
use silicon_rl::report::{self, NodeSummary};
use silicon_rl::rl::baselines;
use silicon_rl::util::json::Json;
use silicon_rl::util::Rng;

fn small_cfg(episodes: usize) -> RunConfig {
    let mut c = RunConfig::default();
    c.rl.episodes_per_node = episodes;
    c.granularity = Granularity::Group;
    c
}

#[test]
fn random_search_two_nodes_generates_full_reports() {
    let cfg = small_cfg(40);
    let mut rng = Rng::new(11);
    let results = vec![
        baselines::random_search(&cfg, 3, &mut rng.fork(1)),
        baselines::random_search(&cfg, 28, &mut rng.fork(2)),
    ];
    let rows: Vec<NodeSummary> =
        results.iter().filter_map(NodeSummary::from_result).collect();
    assert_eq!(rows.len(), 2, "both nodes should find feasible configs");

    // Table 10/11 shape: 3nm faster, smaller, hungrier than 28nm
    let (r3, r28) = (&rows[0], &rows[1]);
    assert!(r3.tokens_per_s > r28.tokens_per_s);
    assert!(r3.area_mm2 < r28.area_mm2);

    // every report table renders + round-trips CSV
    for t in [
        report::nodes_table(&rows),
        report::power_breakdown(&rows),
        report::efficiency_table(&rows),
        report::run_stats(
            &results,
            "test",
            &cfg.scenario(),
            &silicon_rl::nn::kernels::describe(silicon_rl::nn::KernelSel::Auto),
            None,
        ),
        report::industry_comparison(rows.first()),
        report::cross_node_compare(r3, r28),
        report::search_comparison(&[("rand", &results[0])]),
        report::convergence_csv(&results[0].episodes),
    ] {
        let csv = t.to_csv();
        assert!(csv.lines().count() >= 2, "{} is empty", t.title);
        assert!(!t.to_text().is_empty());
    }
}

#[test]
fn llama_compute_ceiling_binds_at_every_node() {
    // §3.8: compute is the active limiter at all nodes for Llama
    let cfg = small_cfg(1);
    for nm in [3, 7, 14, 28] {
        let mut env = Env::new(&cfg, nm);
        let mut a = Action::neutral();
        a.cont[22] = 0.5;
        let out = env.eval_action(&a);
        assert_eq!(
            out.ppa.ceilings.binding(),
            Binding::Compute,
            "{nm}nm: {:?}",
            out.ppa.ceilings
        );
    }
}

#[test]
fn deterministic_given_seed() {
    let cfg = small_cfg(25);
    let a = baselines::random_search(&cfg, 7, &mut Rng::new(42));
    let b = baselines::random_search(&cfg, 7, &mut Rng::new(42));
    for (x, y) in a.episodes.iter().zip(&b.episodes) {
        assert_eq!(x.reward, y.reward);
        assert_eq!(x.mesh_w, y.mesh_w);
    }
    let c = baselines::random_search(&cfg, 7, &mut Rng::new(43));
    assert!(
        a.episodes.iter().zip(&c.episodes).any(|(x, y)| x.reward != y.reward),
        "different seeds should explore differently"
    );
}

#[test]
fn smolvlm_low_power_run_lands_in_mw_regime() {
    let mut cfg = RunConfig::smolvlm_low_power();
    cfg.rl.episodes_per_node = 60;
    cfg.granularity = Granularity::Group;
    let mut rng = Rng::new(5);
    let r = baselines::random_search(&cfg, 3, &mut rng);
    let best = r.best.as_ref().expect("feasible low-power design");
    let o = &best.outcome;
    assert!(o.ppa.power.total() < 15.0, "power {} mW", o.ppa.power.total());
    assert_eq!(o.decoded.avg.clock_mhz, 10.0);
    // compact mesh (paper: 8-12 TCCs)
    assert!(o.decoded.mesh.cores() <= 64, "{} cores", o.decoded.mesh.cores());
    // leakage-dominated at 3nm (§4.12)
    assert!(o.ppa.power.leakage / o.ppa.power.total() > 0.5);
}

#[test]
fn design_artifacts_round_trip_through_json() {
    let cfg = small_cfg(1);
    let mut env = Env::new(&cfg, 3);
    let out = env.eval_action(&Action::neutral());
    let dir = std::env::temp_dir().join("silicon_rl_integration_artifacts");
    silicon_rl::artifacts_out::write_node_artifacts(&dir, 3, &out).unwrap();
    let tiles_text =
        std::fs::read_to_string(dir.join("tcc_config_3nm.json")).unwrap();
    let parsed = Json::parse(&tiles_text).unwrap();
    let tiles = parsed.get("tiles").unwrap().as_arr().unwrap();
    assert_eq!(tiles.len(), out.decoded.mesh.cores());
    // per-tile WMEM in the artifact must cover the placement (Eq 14)
    let total_wmem_kb: f64 = tiles
        .iter()
        .map(|t| t.get("wmem_kb").unwrap().as_f64().unwrap())
        .sum();
    assert!(total_wmem_kb * 1024.0 >= out.ppa.tokens_per_s.min(1.0) * 0.0 + 14.9 * 1e9);
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn workloads_build_and_validate() {
    for w in [Workload::LLAMA31_8B, Workload::SMOLVLM] {
        let g = w.build();
        g.validate().unwrap();
        assert!(g.params > 0.0);
    }
}

#[test]
fn new_workload_scenario_runs_end_to_end_and_is_feasible() {
    // the ISSUE acceptance scenario: a registry-only workload at an
    // explicit (phase, seq_len, batch) point, through the same
    // config → registry → Evaluator → search → report pipeline the
    // `optimize` CLI drives (minus the artifact-backed SAC agent)
    let mut cfg = small_cfg(60);
    cfg.apply("workload", "llama-3.2-1b").unwrap();
    cfg.apply("phase", "decode").unwrap();
    cfg.apply("seq_len", "8192").unwrap();
    cfg.apply("batch", "1").unwrap();
    let mut rng = Rng::new(21);
    let r = baselines::random_search(&cfg, 7, &mut rng);
    let best = r.best.as_ref().expect("feasible design at 7nm");
    let o = &best.outcome;
    assert!(o.reward.feasible);
    assert!(o.ppa.tokens_per_s.is_finite() && o.ppa.tokens_per_s > 0.0);

    // the report pipeline renders for the scenario run
    let rows: Vec<NodeSummary> = NodeSummary::from_result(&r).into_iter().collect();
    assert_eq!(rows.len(), 1);
    let t = report::run_stats(
        std::slice::from_ref(&r),
        "hp",
        &cfg.scenario(),
        &silicon_rl::nn::kernels::describe(silicon_rl::nn::KernelSel::Scalar),
        None,
    );
    let txt = t.to_text();
    assert!(txt.contains("8192"), "{txt}");
    assert!(txt.contains("decode"), "{txt}");
}

#[test]
fn prefill_scenario_runs_without_spec_decode_boost() {
    let mut cfg = small_cfg(1);
    cfg.apply("phase", "prefill").unwrap();
    let mut env = Env::new(&cfg, 7);
    let out = env.eval_action(&Action::neutral());
    // speculative decoding must be off in prefill
    assert_eq!(out.decoded.alpha_spec, 1.0);
    assert!(out.ppa.tokens_per_s.is_finite() && out.ppa.tokens_per_s > 0.0);
}

#[test]
fn vision_encoder_workload_runs_without_kv() {
    let mut cfg = small_cfg(12);
    cfg.apply("workload", "vit-base").unwrap();
    let mut rng = Rng::new(13);
    let r = baselines::random_search(&cfg, 14, &mut rng);
    assert_eq!(r.episodes.len(), 12);
    assert!(r.episodes.iter().all(|e| e.reward.is_finite()));
}

#[test]
fn grid_beats_nothing_random_is_logged_table21_shape() {
    // Table 21 shape: all methods produce finite scores; feasible counts
    // are bounded by episodes
    let cfg = small_cfg(30);
    let mut rng = Rng::new(9);
    let rand_r = baselines::random_search(&cfg, 3, &mut rng.fork(1));
    let grid_r = baselines::grid_search(&cfg, 3, &mut rng.fork(2));
    for r in [&rand_r, &grid_r] {
        assert!(r.feasible_count <= r.total_episodes);
        assert_eq!(r.episodes.len(), 30);
    }
    let t = report::search_comparison(&[
        ("Random Search", &rand_r),
        ("Grid Search", &grid_r),
    ]);
    assert_eq!(t.rows.len(), 2);
}

#[test]
fn kv_compaction_strategies_change_memory_ceiling() {
    use silicon_rl::kv::KvStrategy;
    let mut base = small_cfg(1);
    base.kv_strategy = KvStrategy::Full;
    let mut env_full = Env::new(&base, 3);
    let full = env_full.eval_action(&Action::neutral());

    let mut quant = small_cfg(1);
    quant.kv_strategy = KvStrategy::Quantized { bits: 8 };
    let mut env_q = Env::new(&quant, 3);
    let q = env_q.eval_action(&Action::neutral());

    // Eq 33: compaction relieves the memory ceiling
    assert!(q.ppa.ceilings.memory >= full.ppa.ceilings.memory);
}

#[test]
fn op_granularity_matches_group_granularity_shape() {
    // op-level placement (paper-faithful) should agree with group mode on
    // headline magnitudes (same graph, same knobs)
    let mut cfg_op = small_cfg(1);
    cfg_op.granularity = Granularity::Op;
    let mut cfg_gr = small_cfg(1);
    cfg_gr.granularity = Granularity::Group;
    let mut a = Action::neutral();
    a.cont[22] = 0.5;
    let out_op = Env::new(&cfg_op, 3).eval_action(&a);
    let out_gr = Env::new(&cfg_gr, 3).eval_action(&a);
    let ratio = out_op.ppa.tokens_per_s / out_gr.ppa.tokens_per_s;
    assert!(
        (0.5..2.0).contains(&ratio),
        "op {} vs group {} tok/s",
        out_op.ppa.tokens_per_s,
        out_gr.ppa.tokens_per_s
    );
}
