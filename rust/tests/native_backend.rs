//! Native-backend integration tests: golden-pinned forward/update values
//! from a fixed seed + the builtin manifest (generated from a numpy f32
//! reference whose gradients were validated against JAX autodiff in f64),
//! PJRT↔native parity when AOT artifacts are available, and full-loop
//! seed determinism of `run_node` over the artifact-free native backend.

use std::path::Path;

use silicon_rl::config::{Granularity, RunConfig};
use silicon_rl::env::{ACT_DIM, SAC_STATE_DIM};
use silicon_rl::nn::backend::{self, Backend, BackendSel, SacBatch};
use silicon_rl::nn::{NativeBackend, Store};
use silicon_rl::rl::{run_node, SacAgent};
use silicon_rl::runtime::{self, Manifest};
use silicon_rl::util::Rng;

const B: usize = 8;

fn close(got: f64, want: f64, tol: f64, what: &str) {
    assert!(
        (got - want).abs() <= tol,
        "{what}: got {got}, want {want} (tol {tol})"
    );
}

fn golden_store() -> Store {
    Store::from_manifest(&Manifest::builtin(), &mut Rng::new(42)).unwrap()
}

/// The formula state used for forward goldens (no RNG: reproducible in
/// the python generator without porting more of the rng).
fn formula_state() -> Vec<f32> {
    (0..SAC_STATE_DIM).map(|j| ((j * 37 % 19) as f32 - 9.0) / 10.0).collect()
}

fn formula_action() -> Vec<f32> {
    (0..ACT_DIM).map(|j| ((j * 13 % 17) as f32 - 8.0) / 9.0).collect()
}

/// Deterministic SAC batch (B=8 form mirrored in the golden generator;
/// the PJRT parity test builds it at the manifest batch size, which is
/// baked into the lowered HLO).
struct FormulaBatch {
    n: usize,
    s: Vec<f32>,
    a: Vec<f32>,
    ad: Vec<f32>,
    r: Vec<f32>,
    s2: Vec<f32>,
    done: Vec<f32>,
    w: Vec<f32>,
    eps_cur: Vec<f32>,
    eps_next: Vec<f32>,
}

fn formula_batch_n(n: usize) -> FormulaBatch {
    let mut fb = FormulaBatch {
        n,
        s: Vec::new(),
        a: Vec::new(),
        ad: vec![0.0; n * 20],
        r: Vec::new(),
        s2: Vec::new(),
        done: Vec::new(),
        w: Vec::new(),
        eps_cur: Vec::new(),
        eps_next: Vec::new(),
    };
    for b in 0..n {
        for j in 0..SAC_STATE_DIM {
            fb.s.push(((b * 31 + j * 7) % 23) as f32 - 11.0);
            fb.s2.push(((b * 13 + j * 11) % 29) as f32 - 14.0);
        }
        for j in 0..ACT_DIM {
            fb.a.push((((b * 17 + j * 5) % 19) as f32 - 9.0) / 10.0);
            fb.eps_cur.push((((b * 7 + j * 3) % 11) as f32 - 5.0) / 5.0);
            fb.eps_next.push((((b * 5 + j * 7) % 13) as f32 - 6.0) / 6.0);
        }
        for hd in 0..4 {
            fb.ad[b * 20 + hd * 5 + (b + hd) % 5] = 1.0;
        }
        fb.r.push((b % 5) as f32 / 5.0 - 0.4);
        fb.done.push(if b % 8 == 7 { 1.0 } else { 0.0 });
        fb.w.push(0.5 + (b % 4) as f32 * 0.25);
    }
    for v in fb.s.iter_mut() {
        *v /= 12.0;
    }
    for v in fb.s2.iter_mut() {
        *v /= 15.0;
    }
    fb
}

fn formula_batch() -> FormulaBatch {
    formula_batch_n(B)
}

impl FormulaBatch {
    fn as_sac(&self) -> SacBatch<'_> {
        SacBatch {
            b: self.n,
            s: &self.s,
            a: &self.a,
            ad: &self.ad,
            r: &self.r,
            s2: &self.s2,
            done: &self.done,
            w: &self.w,
            eps_cur: &self.eps_cur,
            eps_next: &self.eps_next,
        }
    }
}

#[test]
fn golden_store_init_from_seed_42() {
    let store = golden_store();
    let w1 = store.get("actor/W1").unwrap();
    let want = [-0.052678239, 0.114133917, -0.010680910, -0.033688478];
    for (i, &w) in want.iter().enumerate() {
        close(w1[i] as f64, w, 2e-6, &format!("actor/W1[{i}]"));
    }
    let ca = store.get("c1/Wa").unwrap();
    close(ca[0] as f64, 0.100990601, 2e-6, "c1/Wa[0]");
    close(
        store.get("wm/W1").unwrap()[0] as f64,
        -0.126766846,
        2e-6,
        "wm/W1[0]",
    );
    close(
        store.get("sur/W3").unwrap()[0] as f64,
        0.318256617,
        2e-6,
        "sur/W3[0]",
    );
    assert_eq!(store.get("t1/Wa").unwrap(), store.get("c1/Wa").unwrap());
}

#[test]
fn golden_actor_forward_b1() {
    let store = golden_store();
    let mut be = NativeBackend::builtin().unwrap();
    let s = formula_state();
    let out = be.actor_fwd(&store, &s).unwrap();
    let want_mu = [-0.42056733, -0.31121859, 0.25972190, -0.09461465, -0.07781739];
    let want_ls = [0.06612194, 0.06876212, 0.35633886, 0.25192374, -0.45657659];
    let want_dl = [0.67383415, 0.37733328, -0.03722780, 0.27964407, 0.53762186];
    for i in 0..5 {
        close(out.mu[i] as f64, want_mu[i], 5e-4, &format!("mu[{i}]"));
        close(out.log_std[i] as f64, want_ls[i], 5e-4, &format!("log_std[{i}]"));
        close(out.disc_logits[i] as f64, want_dl[i], 5e-4, &format!("dl[{i}]"));
    }
}

#[test]
fn golden_wm_and_sur_forward() {
    let store = golden_store();
    let mut be = NativeBackend::builtin().unwrap();
    let s = formula_state();
    let a = formula_action();
    let want_wm = [-0.92537057, 1.48420942, 1.09680748, 1.13664031, -0.02855498];
    {
        let out = be.wm_fwd(&store, &s, &a).unwrap();
        for (i, &w) in want_wm.iter().enumerate() {
            close(out[i] as f64, w, 1e-3, &format!("wm_fwd[{i}]"));
        }
    }
    let want_sur = [0.16345751, 0.59510183, 0.08470958];
    let out = be.sur_fwd(&store, &s, &a).unwrap();
    for (i, &w) in want_sur.iter().enumerate() {
        close(out[i] as f64, w, 1e-3, &format!("sur_fwd[{i}]"));
    }
}

#[test]
fn golden_sac_update_metrics_and_parameters() {
    let mut store = golden_store();
    let mut be = NativeBackend::builtin().unwrap();
    let fb = formula_batch();
    let (metrics, td) = {
        let out = be.sac_update(&mut store, &fb.as_sac()).unwrap();
        (out.metrics, out.td_abs.to_vec())
    };
    close(metrics.critic_loss, 10.092409, 0.02, "critic_loss");
    close(metrics.actor_loss, -2.8521314, 0.02, "actor_loss");
    close(metrics.alpha_loss, -78.378113, 0.1, "alpha_loss");
    close(metrics.alpha, 0.19993998, 2e-4, "alpha");
    close(metrics.entropy, 18.689980, 0.05, "entropy");
    let want_td = [2.3433924, 3.1790543, 2.7728374, 4.5941362];
    for (i, &w) in want_td.iter().enumerate() {
        close(td[i] as f64, w, 0.02, &format!("td_abs[{i}]"));
    }
    close(store.get("log_alpha").unwrap()[0] as f64, -1.6097380, 1e-5, "log_alpha'");
    assert_eq!(store.get("step").unwrap()[0], 1.0);
    close(store.get("actor/b1").unwrap()[0] as f64, -2.9999955e-4, 2e-5, "actor/b1'");
    close(store.get("c1/bc").unwrap()[0] as f64, 3.0000001e-4, 2e-5, "c1/bc'");
    close(store.get("t1/Wa").unwrap()[0] as f64, 0.10099210, 1e-5, "t1/Wa'");
}

#[test]
fn golden_wm_and_sur_update_losses() {
    let mut store = golden_store();
    let mut be = NativeBackend::builtin().unwrap();
    let fb = formula_batch();
    let loss = be.wm_update(&mut store, &fb.s, &fb.a, &fb.s2).unwrap();
    close(loss, 47.006027, 0.05, "wm loss");
    let ppa: Vec<f32> = (0..B).flat_map(|_| [0.4f32, 0.5, 0.3]).collect();
    let loss = be.sur_update(&mut store, &fb.s, &fb.a, &ppa).unwrap();
    close(loss, 1.3077564, 0.005, "sur loss");
}

/// Short Algorithm 1 run over the native backend with NO artifacts
/// required, twice with the same seed: the per-episode logs and the best
/// outcome must be bit-identical.
#[test]
fn native_run_node_is_seed_deterministic() {
    let run = || {
        let mut cfg = RunConfig::default();
        cfg.backend = BackendSel::Native;
        cfg.artifacts_dir = "/nonexistent-artifacts".into();
        cfg.granularity = Granularity::Group;
        cfg.rl.episodes_per_node = 30;
        cfg.rl.warmup_steps = 10_000; // skip updates: keep the test fast
        let be = backend::load(&cfg.artifacts_dir, cfg.backend).unwrap();
        assert_eq!(be.kind(), "native");
        let mut rng = Rng::new(5);
        let mut agent = SacAgent::new(be, cfg.rl, &mut rng).unwrap();
        run_node(&cfg, 3, &mut agent, &mut rng).unwrap()
    };
    let r1 = run();
    let r2 = run();
    assert_eq!(r1.episodes.len(), 30);
    assert!(r1.feasible_count > 0, "no feasible configs in 30 episodes");
    assert!(r1.best.is_some());
    for (a, b) in r1.episodes.iter().zip(&r2.episodes) {
        assert_eq!(a.reward.to_bits(), b.reward.to_bits(), "ep {}", a.episode);
        assert_eq!(a.score.to_bits(), b.score.to_bits(), "ep {}", a.episode);
        assert_eq!(a.entropy.to_bits(), b.entropy.to_bits(), "ep {}", a.episode);
        assert_eq!((a.mesh_w, a.mesh_h), (b.mesh_w, b.mesh_h), "ep {}", a.episode);
        assert_eq!(a.unique_configs, b.unique_configs, "ep {}", a.episode);
    }
    assert_eq!(
        r1.best.as_ref().unwrap().episode,
        r2.best.as_ref().unwrap().episode
    );
}

/// PJRT ↔ native parity over the same manifest + store: gated on built
/// artifacts and a linked PJRT runtime (skips cleanly otherwise).
/// Tolerance-based — XLA and the native kernels accumulate f32 in
/// different orders.
#[test]
fn pjrt_native_parity_when_artifacts_available() {
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !dir.join("manifest.json").exists() || !runtime::backend_available() {
        eprintln!("parity: artifacts or PJRT unavailable; skipping");
        return;
    }
    let adir = dir.to_string_lossy().to_string();
    let mut pjrt = backend::load(&adir, BackendSel::Pjrt).unwrap();
    let mut native = backend::load(&adir, BackendSel::Native).unwrap();
    // identical manifests ⇒ identical seed-42 store init on both paths
    let mut store_p = Store::from_manifest(pjrt.manifest(), &mut Rng::new(42)).unwrap();
    let mut store_n = Store::from_manifest(native.manifest(), &mut Rng::new(42)).unwrap();
    assert_eq!(store_p.data, store_n.data, "store init differs across manifests");

    let s = formula_state();
    {
        let op = pjrt.actor_fwd(&store_p, &s).unwrap();
        let mu_p = op.mu.to_vec();
        let ls_p = op.log_std.to_vec();
        let dl_p = op.disc_logits.to_vec();
        let on = native.actor_fwd(&store_n, &s).unwrap();
        for i in 0..ACT_DIM {
            close(on.mu[i] as f64, mu_p[i] as f64, 1e-3, &format!("parity mu[{i}]"));
            close(on.log_std[i] as f64, ls_p[i] as f64, 1e-3, &format!("parity ls[{i}]"));
        }
        for i in 0..20 {
            close(on.disc_logits[i] as f64, dl_p[i] as f64, 1e-3, &format!("parity dl[{i}]"));
        }
    }

    // one fused SAC step on the same batch (at the manifest batch size —
    // baked into the lowered HLO): metrics and every updated store array
    // agree within tolerance
    let bsz = pjrt.manifest().hyper_or("batch", 256.0) as usize;
    let fb = formula_batch_n(bsz);
    let mp = pjrt.sac_update(&mut store_p, &fb.as_sac()).unwrap().metrics;
    let mn = native.sac_update(&mut store_n, &fb.as_sac()).unwrap().metrics;
    close(mn.critic_loss, mp.critic_loss, 0.05, "parity critic_loss");
    close(mn.actor_loss, mp.actor_loss, 0.05, "parity actor_loss");
    close(mn.alpha, mp.alpha, 1e-3, "parity alpha");
    close(mn.entropy, mp.entropy, 0.1, "parity entropy");
    for (name, vp) in &store_p.data {
        let vn = &store_n.data[name];
        assert_eq!(vp.len(), vn.len(), "{name} length");
        let scale = vp.iter().fold(1.0f32, |m, v| m.max(v.abs())) as f64;
        for (i, (&a, &b)) in vp.iter().zip(vn).enumerate() {
            let d = (a as f64 - b as f64).abs();
            assert!(
                d <= 1e-4 + 1e-3 * scale,
                "parity {name}[{i}]: pjrt {a} native {b}"
            );
        }
    }

    // world-model update losses agree
    let lp = pjrt.wm_update(&mut store_p, &fb.s, &fb.a, &fb.s2).unwrap();
    let ln = native.wm_update(&mut store_n, &fb.s, &fb.a, &fb.s2).unwrap();
    close(ln, lp, 0.05, "parity wm loss");
}
