//! Golden equivalence contract of the stage-split + roofline-pruned
//! evaluation pipeline (DESIGN.md §5):
//!
//! 1. the staged pipeline with warm per-stage memos is bit-identical to
//!    a fresh-scratch evaluation for every input, on every process node;
//! 2. the roofline admission bound is admissible — it never exceeds the
//!    true composite score of a full evaluation;
//! 3. pruned batch evaluation selects a bit-identical argmax outcome to
//!    the exact scan, at any worker count;
//! 4. search drivers produce identical best designs with pruning on.
//!
//! Everything runs the analytical pipeline — no AOT artifacts needed.

use silicon_rl::config::{Granularity, RunConfig};
use silicon_rl::env::Action;
use silicon_rl::eval::{EvalOutcome, EvalScratch, Evaluator};
use silicon_rl::rl::{baselines, run_seeds_t};
use silicon_rl::util::Rng;

const ALL_NODES: [u32; 7] = [3, 5, 7, 10, 14, 22, 28];

fn small_cfg() -> RunConfig {
    let mut c = RunConfig::default();
    c.granularity = Granularity::Group;
    c
}

fn random_action(rng: &mut Rng) -> Action {
    let mut a = Action::neutral();
    for v in a.cont.iter_mut() {
        *v = rng.uniform_in(-1.0, 1.0);
    }
    for d in a.deltas.iter_mut() {
        *d = rng.below(5) as i32 - 2;
    }
    a
}

fn assert_outcomes_identical(a: &EvalOutcome, b: &EvalOutcome, what: &str) {
    assert_eq!(a.reward.total.to_bits(), b.reward.total.to_bits(), "{what}: reward");
    assert_eq!(a.reward.score.to_bits(), b.reward.score.to_bits(), "{what}: score");
    assert_eq!(a.reward.feasible, b.reward.feasible, "{what}: feasible");
    assert_eq!(
        a.ppa.tokens_per_s.to_bits(),
        b.ppa.tokens_per_s.to_bits(),
        "{what}: tokens/s"
    );
    assert_eq!(
        a.ppa.power.total().to_bits(),
        b.ppa.power.total().to_bits(),
        "{what}: power"
    );
    assert_eq!(a.decoded.mesh, b.decoded.mesh, "{what}: mesh");
    assert_eq!(a.proj_steps, b.proj_steps, "{what}: projection steps");
    assert_eq!(a.tiles.len(), b.tiles.len(), "{what}: tile count");
    for (i, (x, y)) in a.full_state.iter().zip(&b.full_state).enumerate() {
        assert_eq!(x.to_bits(), y.to_bits(), "{what}: state dim {i}");
    }
}

#[test]
fn staged_pipeline_with_warm_memos_is_bit_identical() {
    // a mesh-walking random sweep on every node: one warm scratch (stage
    // memos accumulate) vs a fresh scratch per evaluation
    let cfg = small_cfg();
    for nm in ALL_NODES {
        let ev = Evaluator::new(&cfg, nm);
        let mut mesh = ev.initial_mesh();
        let mut rng = Rng::new(100 + nm as u64);
        let mut warm = EvalScratch::default();
        for i in 0..8 {
            let a = random_action(&mut rng);
            let cached = ev.evaluate(&mesh, &a, &mut warm);
            let fresh = ev.evaluate(&mesh, &a, &mut EvalScratch::default());
            assert_outcomes_identical(&cached, &fresh, &format!("{nm}nm, action {i}"));
            mesh = cached.decoded.mesh;
        }
        // force a placement-memo hit and re-check equivalence
        let a = random_action(&mut rng);
        ev.evaluate(&mesh, &a, &mut warm);
        let hits_before = warm.stages.hits;
        let replayed = ev.evaluate(&mesh, &a, &mut warm);
        assert!(warm.stages.hits > hits_before, "{nm}nm: stage memo never hit");
        let fresh = ev.evaluate(&mesh, &a, &mut EvalScratch::default());
        assert_outcomes_identical(&replayed, &fresh, &format!("{nm}nm, memo hit"));
    }
}

#[test]
fn admission_bound_is_admissible_on_all_nodes() {
    // the pruning soundness invariant: bound ≤ true score, for random
    // actions on every process node (high-performance and low-power)
    for cfg in [small_cfg(), {
        let mut c = RunConfig::smolvlm_low_power();
        c.granularity = Granularity::Group;
        c
    }] {
        for nm in ALL_NODES {
            let ev = Evaluator::new(&cfg, nm);
            let mut mesh = ev.initial_mesh();
            let mut rng = Rng::new(7 + nm as u64);
            let mut scratch = EvalScratch::default();
            for i in 0..10 {
                let a = random_action(&mut rng);
                let (decoded, _) = ev.stage_decode(&mesh, &a);
                let bound = ev.admission_bound(&decoded);
                let out = ev.evaluate(&mesh, &a, &mut scratch);
                assert!(
                    bound <= out.reward.score + 1e-9,
                    "{nm}nm action {i}: bound {bound} exceeds score {}",
                    out.reward.score
                );
                mesh = out.decoded.mesh;
            }
        }
    }
}

#[test]
fn pruned_batch_argmax_is_bit_identical_to_exact() {
    let cfg = small_cfg();
    for nm in ALL_NODES {
        let ev = Evaluator::new(&cfg, nm);
        let mut mesh = ev.initial_mesh();
        let mut rng = Rng::new(40 + nm as u64);
        for round in 0..2 {
            let actions: Vec<Action> =
                (0..10).map(|_| random_action(&mut rng)).collect();
            let exact = ev.evaluate_best(&mesh, &actions, 1, false);
            assert_eq!(exact.n_pruned, 0);
            for threads in [1usize, 4] {
                let pruned = ev.evaluate_best(&mesh, &actions, threads, true);
                assert_eq!(
                    exact.best, pruned.best,
                    "{nm}nm round {round}, {threads} threads: selection diverged"
                );
                assert_outcomes_identical(
                    exact.best_outcome(),
                    pruned.best_outcome(),
                    &format!("{nm}nm round {round}, {threads} threads"),
                );
                // pruned candidates are a subset; every survivor matches
                // its exact counterpart bit-for-bit
                for (i, o) in pruned.outcomes.iter().enumerate() {
                    if let Some(o) = o {
                        assert_outcomes_identical(
                            exact.outcomes[i].as_ref().unwrap(),
                            o,
                            &format!("{nm}nm round {round}, survivor {i}"),
                        );
                    }
                }
            }
            mesh = exact.best_outcome().decoded.mesh;
        }
    }
}

#[test]
fn pruned_random_search_walks_and_ranks_identically() {
    // the mesh walk is driven by the round argmax, so the full search
    // trajectory (not just the final best) must match the exact path
    let mut exact_cfg = small_cfg();
    exact_cfg.rl.episodes_per_node = 32;
    let mut pruned_cfg = exact_cfg.clone();
    pruned_cfg.rl.prune = true;

    let exact = baselines::random_search_t(&exact_cfg, 7, &mut Rng::new(5), 2);
    let pruned = baselines::random_search_t(&pruned_cfg, 7, &mut Rng::new(5), 2);

    match (&exact.best, &pruned.best) {
        (Some(a), Some(b)) => {
            assert_eq!(a.episode, b.episode, "best episode diverged");
            assert_outcomes_identical(&a.outcome, &b.outcome, "best outcome");
        }
        (None, None) => {}
        _ => panic!("best presence diverged under pruning"),
    }
    // the pruned episode log is a subsequence of the exact one: every
    // surviving episode index carries identical numbers
    let mut exact_by_ep = std::collections::HashMap::new();
    for e in &exact.episodes {
        exact_by_ep.insert(e.episode, e);
    }
    assert!(pruned.episodes.len() <= exact.episodes.len());
    for e in &pruned.episodes {
        let x = exact_by_ep[&e.episode];
        assert_eq!(e.reward.to_bits(), x.reward.to_bits());
        assert_eq!(e.score.to_bits(), x.score.to_bits());
        assert_eq!((e.mesh_w, e.mesh_h), (x.mesh_w, x.mesh_h));
    }
    // documented metric skew: feasible_count only counts evaluated
    // candidates, so under pruning it is a lower bound on the exact value
    // (the episode budget itself is unchanged)
    assert!(pruned.feasible_count <= exact.feasible_count);
    assert_eq!(pruned.total_episodes, exact.total_episodes);
}

#[test]
fn multiseed_best_statistics_identical_under_pruning() {
    let mut exact_cfg = small_cfg();
    exact_cfg.rl.episodes_per_node = 16;
    let mut pruned_cfg = exact_cfg.clone();
    pruned_cfg.rl.prune = true;
    let search = |c: &RunConfig, nm: u32, rng: &mut Rng| {
        baselines::random_search_t(c, nm, rng, 1)
    };
    let exact = run_seeds_t(&exact_cfg, 3, 3, 2, search);
    let pruned = run_seeds_t(&pruned_cfg, 3, 3, 2, search);
    assert_eq!(exact.seeds, pruned.seeds);
    assert_eq!(exact.failures, pruned.failures);
    // per-seed bests are identical, so the aggregated statistics are too
    for (a, b) in [
        (exact.tokens_per_s, pruned.tokens_per_s),
        (exact.power_mw, pruned.power_mw),
        (exact.area_mm2, pruned.area_mm2),
        (exact.score, pruned.score),
    ] {
        assert_eq!(a.mean.to_bits(), b.mean.to_bits());
        assert_eq!(a.std.to_bits(), b.std.to_bits());
    }
}
