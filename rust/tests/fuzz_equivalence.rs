//! Integration suite for the randomized equivalence fuzz harness
//! (`rl::fuzz`, DESIGN.md §14): generator determinism, a budgeted
//! randomized sweep over the evaluator-layer oracles (the named CI
//! smoke), explicit engine-class cases, and the mutation smoke that
//! pins the shrinker — an intentionally-broken oracle must yield a
//! minimal reproducer that still fails.
//!
//! The `simd-scalar` class is deliberately absent: it flips the
//! process-global kernel dispatch, and by repo convention only
//! `tests/kernel_parity.rs` may do that among test binaries. That class
//! runs from the `silicon-rl fuzz` CLI (its own process) instead.

use silicon_rl::error::Result;
use silicon_rl::rl::fuzz::{self, Artifact, CaseGen, FuzzCase, Mismatch};

/// Oracles cheap enough for a per-commit randomized sweep: the
/// evaluator-layer classes (paired batch evaluations / two short
/// `run_node` runs), not the multi-run engine classes.
const CHEAP_CLASSES: [&str; 4] =
    ["serial-parallel", "staged-fresh", "pruned-exact", "cache-nocache"];

fn kv(pairs: &[(&str, &str)]) -> Vec<(String, String)> {
    pairs.iter().map(|(k, v)| (k.to_string(), v.to_string())).collect()
}

#[test]
fn fuzz_generator_is_seed_stable() {
    let classes = fuzz::class_names();
    let fps = |seed: u64| -> Vec<String> {
        let mut g = CaseGen::new(seed, &classes).unwrap();
        (0..16).map(|_| g.next_case().fingerprint()).collect()
    };
    assert_eq!(fps(42), fps(42), "same seed must replay the same case stream");
    assert_ne!(fps(42), fps(43), "different seeds should diverge");
}

#[test]
fn unknown_class_and_oracle_are_rejected() {
    assert!(CaseGen::new(1, &["no-such-class"]).is_err());
    assert!(CaseGen::new(1, &[]).is_err());
    assert!(FuzzCase::from_kv("no-such-oracle", &[]).is_err());
    assert!(FuzzCase::from_repro("episodes = 4\n").is_err(), "missing oracle line");
}

/// The named tier-1 smoke (referenced by CI): a short randomized sweep
/// over the evaluator-layer equivalence classes must come back clean.
#[test]
fn fuzz_randomized_equivalence_smoke() {
    let mut g = CaseGen::new(42, &CHEAP_CLASSES).unwrap();
    for i in 0..6 {
        let case = g.next_case();
        if let Some(m) = fuzz::run_case(&case).unwrap() {
            panic!("case {i} ({}) violated its contract: {m}", case.cmd_line());
        }
    }
}

/// The engine-layer oracles at explicit small cases: B-lane vec-env vs
/// B serial runs, kill→resume vs uninterrupted, pinned vs inline.
#[test]
fn engine_class_oracles_hold_at_explicit_cases() {
    let cases = [
        FuzzCase::from_kv(
            "vec-serial",
            &kv(&[
                ("nodes", "7"),
                ("seed", "7"),
                ("episodes", "6"),
                ("lanes", "2"),
                ("fuzz_action_seed", "11"),
            ]),
        )
        .unwrap(),
        FuzzCase::from_kv(
            "crash-resume",
            &kv(&[
                ("nodes", "7"),
                ("seed", "9"),
                ("episodes", "8"),
                ("lanes", "2"),
                ("checkpoint_every", "2"),
                ("crash_after", "10"),
                ("fuzz_action_seed", "13"),
            ]),
        )
        .unwrap(),
        FuzzCase::from_kv(
            "pinned-inline",
            &kv(&[
                ("nodes", "7"),
                ("seed", "5"),
                ("episodes", "8"),
                ("lanes", "2"),
                ("fuzz_action_seed", "17"),
            ]),
        )
        .unwrap(),
    ];
    for case in &cases {
        if let Some(m) = fuzz::run_case(case).unwrap() {
            panic!("{} violated its contract: {m}", case.cmd_line());
        }
    }
}

/// Mutation smoke: against an intentionally-broken oracle (fails
/// whenever episodes ≥ 3 and lanes ≥ 2), the shrinker must reach the
/// axis minima, push every knob back to its default, and hand back a
/// reproducer that still fails and round-trips through the repro file.
#[test]
fn shrinker_minimizes_and_output_still_fails() {
    let case = FuzzCase::from_kv(
        "vec-serial",
        &kv(&[
            ("nodes", "7,28"),
            ("seed", "3"),
            ("episodes", "24"),
            ("lanes", "4"),
            ("seq_len", "2048"),
            ("mode", "lp"),
            ("fuzz_batch", "9"),
        ]),
    )
    .unwrap();

    let broken = |c: &FuzzCase| -> Result<Option<Mismatch>> {
        Ok((c.cfg.rl.episodes_per_node >= 3 && c.cfg.rl.lanes >= 2).then(|| Mismatch {
            oracle: "vec-serial",
            artifact: Artifact::Scalar { name: "synthetic".into() },
            left: "left".into(),
            right: "right".into(),
        }))
    };

    let out = fuzz::shrink_with(&case, &broken, 10_000)
        .unwrap()
        .expect("the inflated case must fail the broken oracle");
    assert_eq!(out.case.cfg.rl.episodes_per_node, 3, "episodes not at the minimum");
    assert_eq!(out.case.cfg.rl.lanes, 2, "lanes not at the minimum");
    assert_eq!(out.case.batch, 1, "fuzz batch not at the minimum");
    assert_eq!(out.case.rounds, 1, "fuzz rounds not at the minimum");
    assert_eq!(out.case.cfg.nodes_nm, vec![7], "node list not reduced");
    assert_eq!(out.case.cfg.seq_len, None, "seq_len not reset to default");
    assert_eq!(out.case.cfg.mode.name, "high-performance", "mode not reset");
    assert!(out.accepted > 0 && out.attempts > out.accepted);

    // the shrunk case still fails the oracle that produced it
    assert!(
        broken(&out.case).unwrap().is_some(),
        "shrinker returned a config that no longer fails"
    );

    // and it round-trips: file → case → identical fingerprint/CLI
    let text = out.case.to_repro();
    let back = FuzzCase::from_repro(&text).unwrap();
    assert_eq!(back.fingerprint(), out.case.fingerprint(), "repro drift:\n{text}");
    assert!(out.case.cmd_line().starts_with("silicon-rl fuzz oracle=vec-serial"));
}

/// A passing case must not be "shrunk" — the shrinker only engages on a
/// confirmed failure.
#[test]
fn shrinker_ignores_passing_cases() {
    let case = FuzzCase::from_kv("vec-serial", &kv(&[("episodes", "4")])).unwrap();
    let pass = |_: &FuzzCase| -> Result<Option<Mismatch>> { Ok(None) };
    assert!(fuzz::shrink_with(&case, &pass, 100).unwrap().is_none());
}
