//! Crash-safe checkpoint/resume golden suite: the robustness contract of
//! DESIGN.md §13.
//!
//! * Kill-and-resume **bit-identity**: a run interrupted by the
//!   fault-injection harness (`crash_after=N`) and resumed from its
//!   newest checkpoint produces episode logs, Pareto frontiers and replay
//!   contents bit-identical to the uninterrupted run — per required seeds
//!   {7, 42} at 7nm, at randomized crash points, through the live-update
//!   region, and under the pinned off-loop learner.
//! * Corruption fallback: a torn newest generation falls back to the
//!   previous one (still bit-identical — any valid generation is a
//!   correct resume point); two torn slots start fresh (also identical);
//!   a foreign fingerprint is a hard error, never a silent wrong resume.
//! * Graceful learner degradation: an injected learner-thread failure
//!   (`learner_fail_after=N`) falls the run back to inline updates and
//!   surfaces in the report instead of killing the search.
//! * The atlas sweep checkpoints at group boundaries and resumes
//!   bit-identically on a reduced grid.
//!
//! Codec round-trip and slot-scheme unit tests live in `rl::checkpoint`'s
//! own `#[cfg(test)]` module; the mid-wave vec-env kill lives in
//! `rl::vecenv`'s.

use std::path::{Path, PathBuf};

use silicon_rl::config::{Granularity, RunConfig};
use silicon_rl::env::{ACT_DIM, SAC_STATE_DIM};
use silicon_rl::nn::backend::{self, BackendSel};
use silicon_rl::rl::checkpoint::INJECTED_CRASH_MSG;
use silicon_rl::rl::{self, LaneSpec, NodeResult, SacAgent};
use silicon_rl::util::fsio::{self, ByteReader};
use silicon_rl::util::Rng;

/// The acceptance lanes: required seeds {7, 42} at 7nm.
const SPECS7: [LaneSpec; 2] = [LaneSpec { nm: 7, seed: 7 }, LaneSpec { nm: 7, seed: 42 }];

/// Wider lane set whose buffer crosses the minibatch gate (256) at step
/// 63 of a 66-episode run — the last steps exercise live SAC updates, so
/// checkpoints in that window carry mid-training parameter state.
const SPECS4: [LaneSpec; 4] = [
    LaneSpec { nm: 7, seed: 7 },
    LaneSpec { nm: 7, seed: 42 },
    LaneSpec { nm: 28, seed: 7 },
    LaneSpec { nm: 28, seed: 42 },
];

fn base_cfg(episodes: usize) -> RunConfig {
    let mut cfg = RunConfig::default();
    cfg.backend = BackendSel::Native;
    cfg.artifacts_dir = "/nonexistent-artifacts".into();
    cfg.granularity = Granularity::Group;
    cfg.rl.episodes_per_node = episodes;
    cfg.rl.warmup_steps = 8;
    cfg
}

/// Fresh agent with the pinned seed-42 store init (same init for the
/// reference, the crashed run and the resume — the resume overwrites it
/// from the checkpoint; a crash-before-first-save resume must re-derive
/// it identically).
fn fresh_agent(cfg: &RunConfig) -> SacAgent {
    let be = backend::load(&cfg.artifacts_dir, cfg.backend).unwrap();
    SacAgent::new(be, cfg.rl, &mut Rng::new(42)).unwrap()
}

type Run = (Vec<NodeResult>, SacAgent, Option<rl::LearnerReport>);

fn run(
    cfg: &RunConfig,
    specs: &[LaneSpec],
    lanes: usize,
    threads: usize,
) -> silicon_rl::error::Result<Run> {
    let mut agent = fresh_agent(cfg);
    let (results, report) = rl::run_jobs_stats(cfg, specs, lanes, &mut agent, threads)?;
    Ok((results, agent, report))
}

/// Fresh scratch out_dir for one test (checkpoints land in `<dir>/ckpt`).
fn tmp_out(name: &str) -> PathBuf {
    let p = std::env::temp_dir().join(format!("silckpt-it-{name}-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&p);
    p
}

fn assert_logs_identical(a: &NodeResult, b: &NodeResult, what: &str) {
    assert_eq!(a.episodes.len(), b.episodes.len(), "{what}: episode count");
    for (x, y) in a.episodes.iter().zip(&b.episodes) {
        let ep = x.episode;
        assert_eq!(x.reward.to_bits(), y.reward.to_bits(), "{what} ep {ep}: reward");
        assert_eq!(x.score.to_bits(), y.score.to_bits(), "{what} ep {ep}: score");
        assert_eq!(
            x.best_score.to_bits(),
            y.best_score.to_bits(),
            "{what} ep {ep}: best_score"
        );
        assert_eq!(x.feasible, y.feasible, "{what} ep {ep}: feasible");
        assert_eq!(x.eps.to_bits(), y.eps.to_bits(), "{what} ep {ep}: eps");
        assert_eq!(x.entropy.to_bits(), y.entropy.to_bits(), "{what} ep {ep}: entropy");
        assert_eq!((x.mesh_w, x.mesh_h), (y.mesh_w, y.mesh_h), "{what} ep {ep}: mesh");
        assert_eq!(x.unique_configs, y.unique_configs, "{what} ep {ep}: unique");
    }
    assert_eq!(a.feasible_count, b.feasible_count, "{what}: feasible_count");
}

fn assert_frontiers_identical(a: &NodeResult, b: &NodeResult, what: &str) {
    let (fa, fb) = (a.pareto.frontier(), b.pareto.frontier());
    assert_eq!(fa.len(), fb.len(), "{what}: frontier size");
    for (p, q) in fa.iter().zip(fb) {
        assert_eq!(p.perf_gops.to_bits(), q.perf_gops.to_bits(), "{what}: perf");
        assert_eq!(p.power_mw.to_bits(), q.power_mw.to_bits(), "{what}: power");
        assert_eq!(p.area_mm2.to_bits(), q.area_mm2.to_bits(), "{what}: area");
        assert_eq!(p.episode, q.episode, "{what}: episode tag");
    }
}

fn assert_buffers_identical(a: &SacAgent, b: &SacAgent, what: &str) {
    assert_eq!(a.buffer.len(), b.buffer.len(), "{what}: buffer length");
    for t in 0..a.buffer.len() {
        let (x, y) = (a.buffer.get(t), b.buffer.get(t));
        assert_eq!(x.r.to_bits(), y.r.to_bits(), "{what} slot {t}: reward");
        assert_eq!(x.done.to_bits(), y.done.to_bits(), "{what} slot {t}: done");
        for j in 0..SAC_STATE_DIM {
            assert_eq!(x.s[j].to_bits(), y.s[j].to_bits(), "{what} slot {t}: s[{j}]");
            assert_eq!(x.s2[j].to_bits(), y.s2[j].to_bits(), "{what} slot {t}: s2[{j}]");
        }
        for j in 0..ACT_DIM {
            assert_eq!(
                x.a_cont[j].to_bits(),
                y.a_cont[j].to_bits(),
                "{what} slot {t}: a[{j}]"
            );
        }
        assert_eq!(x.a_disc, y.a_disc, "{what} slot {t}: a_disc");
        for j in 0..3 {
            assert_eq!(x.ppa[j].to_bits(), y.ppa[j].to_bits(), "{what} slot {t}: ppa[{j}]");
        }
    }
}

fn assert_run_matches(reference: &Run, resumed: &Run, what: &str) {
    for (lane, (a, b)) in reference.0.iter().zip(&resumed.0).enumerate() {
        assert_logs_identical(a, b, &format!("{what} lane {lane}"));
        assert_frontiers_identical(a, b, &format!("{what} lane {lane}"));
    }
    assert_buffers_identical(&reference.1, &resumed.1, what);
    assert_eq!(
        reference.1.updates_done, resumed.1.updates_done,
        "{what}: update count diverged"
    );
}

/// Parse a slot file's generation sequence number (layout: sealed record
/// whose payload opens with `seq: u64`).
fn slot_seq(path: &Path) -> Option<u64> {
    let bytes = std::fs::read(path).ok()?;
    let (_kind, payload) = fsio::open_record(&bytes).ok()?;
    ByteReader::new(payload).u64().ok()
}

/// Truncate a slot file to half its length — a torn write.
fn tear_slot(path: &Path) {
    let bytes = std::fs::read(path).unwrap();
    std::fs::write(path, &bytes[..bytes.len() / 2]).unwrap();
}

/// Acceptance core: seeds {7, 42} at 7nm, killed at a step boundary
/// after two checkpoint generations, resumed — episode logs, frontiers
/// and replay contents bit-identical to the uninterrupted run. The
/// resumed run keeps checkpointing, so generation numbering also
/// continues past the restored one.
#[test]
fn crash_resume_bit_identical_seeds_7_42_at_7nm() {
    let cfg = base_cfg(66);
    let reference = run(&cfg, &SPECS7, 2, 1).unwrap();

    let out = tmp_out("accept");
    let mut ccfg = cfg.clone();
    ccfg.out_dir = out.to_string_lossy().into_owned();
    ccfg.rl.checkpoint_every = 16;
    // probe A of step 33 (3·33+1): right after the t=32 save committed
    ccfg.rl.crash_after = 100;
    let err = run(&ccfg, &SPECS7, 2, 1).unwrap_err();
    assert!(format!("{err:#}").contains(INJECTED_CRASH_MSG), "{err:#}");

    let mut rcfg = ccfg.clone();
    rcfg.rl.crash_after = 0;
    rcfg.resume = Some(ccfg.out_dir.clone());
    let resumed = run(&rcfg, &SPECS7, 2, 1).unwrap();
    assert_run_matches(&reference, &resumed, "accept resume");

    // the resume appended generations past the two it restored from
    let newest = [out.join("ckpt/ckpt-a.bin"), out.join("ckpt/ckpt-b.bin")]
        .iter()
        .filter_map(|p| slot_seq(p))
        .max()
        .unwrap();
    assert!(newest >= 3, "resume did not continue the generation sequence: {newest}");
    let _ = std::fs::remove_dir_all(&out);
}

/// The same contract through the live-update region: with 4 lanes the
/// minibatch gate opens at step 63, so the t=64 checkpoint carries
/// mid-training parameters, PER priorities and the update-stream RNG
/// position — and the kill lands mid-wave after the env fan-out.
#[test]
fn crash_resume_bit_identical_through_live_updates() {
    let cfg = base_cfg(66);
    let reference = run(&cfg, &SPECS4, 4, 2).unwrap();
    assert!(reference.1.updates_done > 0, "updates never fired");

    let out = tmp_out("live");
    let mut ccfg = cfg.clone();
    ccfg.out_dir = out.to_string_lossy().into_owned();
    ccfg.rl.checkpoint_every = 16;
    // probe B of step 64 (3·64+2): after the t=64 save, after the env
    // fan-out, one step into the live-update window
    ccfg.rl.crash_after = 194;
    let err = run(&ccfg, &SPECS4, 4, 2).unwrap_err();
    assert!(format!("{err:#}").contains(INJECTED_CRASH_MSG), "{err:#}");

    let mut rcfg = ccfg.clone();
    rcfg.rl.crash_after = 0;
    rcfg.resume = Some(ccfg.out_dir.clone());
    let resumed = run(&rcfg, &SPECS4, 4, 2).unwrap();
    assert_run_matches(&reference, &resumed, "live resume");
    let _ = std::fs::remove_dir_all(&out);
}

/// Randomized crash points: `crash_after` drawn from the whole probe
/// range (3 probes per step), including points before the first
/// checkpoint exists (resume then starts fresh — and must still match).
#[test]
fn randomized_crash_points_resume_identical() {
    let cfg = base_cfg(40);
    let reference = run(&cfg, &SPECS7, 2, 1).unwrap();

    let mut rng = Rng::new(0xC0FFEE);
    for k in 0..3 {
        // 40 steps × 3 probes = 120 probes; stay below so every draw kills
        let crash_after = 1 + rng.below(115) as u64;
        let out = tmp_out(&format!("rand{k}"));
        let mut ccfg = cfg.clone();
        ccfg.out_dir = out.to_string_lossy().into_owned();
        ccfg.rl.checkpoint_every = 8;
        ccfg.rl.crash_after = crash_after;
        let err = run(&ccfg, &SPECS7, 2, 1).unwrap_err();
        assert!(
            format!("{err:#}").contains(INJECTED_CRASH_MSG),
            "crash_after={crash_after}: {err:#}"
        );

        let mut rcfg = ccfg.clone();
        rcfg.rl.crash_after = 0;
        rcfg.resume = Some(ccfg.out_dir.clone());
        let resumed = run(&rcfg, &SPECS7, 2, 1).unwrap();
        assert_run_matches(&reference, &resumed, &format!("crash_after={crash_after}"));
        let _ = std::fs::remove_dir_all(&out);
    }
}

/// Corruption ladder: tear the newest generation → resume falls back to
/// the previous one (bit-identical — any valid generation is a correct
/// resume point); tear both → resume starts fresh (still identical);
/// a checkpoint from a different run configuration → hard error.
#[test]
fn corrupt_checkpoint_falls_back_then_fresh_then_rejects_foreign() {
    let cfg = base_cfg(66);
    let reference = run(&cfg, &SPECS7, 2, 1).unwrap();

    // an uninterrupted checkpointing run: generations at t=16/32/48/64
    let out = tmp_out("corrupt");
    let mut wcfg = cfg.clone();
    wcfg.out_dir = out.to_string_lossy().into_owned();
    wcfg.rl.checkpoint_every = 16;
    run(&wcfg, &SPECS7, 2, 1).unwrap();

    let slots = [out.join("ckpt/ckpt-a.bin"), out.join("ckpt/ckpt-b.bin")];
    let seqs = [slot_seq(&slots[0]).unwrap(), slot_seq(&slots[1]).unwrap()];
    let (newest, oldest) = if seqs[0] > seqs[1] { (0, 1) } else { (1, 0) };

    // 1) torn newest → previous generation, still bit-identical
    tear_slot(&slots[newest]);
    let mut rcfg = cfg.clone();
    rcfg.resume = Some(wcfg.out_dir.clone());
    let resumed = run(&rcfg, &SPECS7, 2, 1).unwrap();
    assert_run_matches(&reference, &resumed, "fallback generation");

    // 2) both torn → fresh start, still bit-identical
    tear_slot(&slots[oldest]);
    let fresh = run(&rcfg, &SPECS7, 2, 1).unwrap();
    assert_run_matches(&reference, &fresh, "fresh after double corruption");

    // 3) foreign fingerprint (different base seed) → refuse, don't guess
    let out2 = tmp_out("foreign");
    let mut w2 = cfg.clone();
    w2.out_dir = out2.to_string_lossy().into_owned();
    w2.rl.checkpoint_every = 16;
    run(&w2, &SPECS7, 2, 1).unwrap();
    let mut f2 = cfg.clone();
    f2.seed = cfg.seed + 1;
    f2.resume = Some(w2.out_dir.clone());
    let err = run(&f2, &SPECS7, 2, 1).unwrap_err();
    assert!(
        format!("{err:#}").contains("different run configuration"),
        "{err:#}"
    );
    let _ = std::fs::remove_dir_all(&out);
    let _ = std::fs::remove_dir_all(&out2);
}

/// Kill-and-resume under the pinned off-loop learner: the checkpoint
/// quiesces the learner thread (its replay buffer, update-stream RNG and
/// counters), the kill lands after a send while the queue is non-empty,
/// and the resumed pinned run is bit-identical to the plain inline
/// reference — the §11 identity contract surviving a crash.
#[test]
fn pinned_learner_crash_resume_bit_identical_to_inline() {
    let cfg = base_cfg(66);
    let reference = run(&cfg, &SPECS4, 4, 2).unwrap();

    let out = tmp_out("pinned");
    let mut ccfg = cfg.clone();
    ccfg.apply("learner", "pinned").unwrap();
    ccfg.out_dir = out.to_string_lossy().into_owned();
    ccfg.rl.checkpoint_every = 16;
    // probe C of step 64 (3·64+3): right after the step's transitions
    // were queued to the learner and before it necessarily drained them
    ccfg.rl.crash_after = 195;
    let err = run(&ccfg, &SPECS4, 4, 2).unwrap_err();
    assert!(format!("{err:#}").contains(INJECTED_CRASH_MSG), "{err:#}");

    let mut rcfg = ccfg.clone();
    rcfg.rl.crash_after = 0;
    rcfg.resume = Some(ccfg.out_dir.clone());
    let resumed = run(&rcfg, &SPECS4, 4, 2).unwrap();
    assert_run_matches(&reference, &resumed, "pinned resume");
    let rep = resumed.2.expect("off-loop learner always reports");
    assert_eq!(rep.steps, 66, "restored learner counters continue the step count");
    assert!(rep.degraded.is_none());
    let _ = std::fs::remove_dir_all(&out);
}

/// Graceful degradation: an injected learner-thread failure mid-run
/// falls back to inline updates — the run completes, the failure is
/// surfaced in the report/banner, and checkpointing quietly stops (the
/// quiesceable state died with the thread) instead of erroring.
#[test]
fn learner_failure_degrades_to_inline_and_is_surfaced() {
    let out = tmp_out("degrade");
    let mut cfg = base_cfg(66);
    cfg.apply("learner", "pinned").unwrap();
    cfg.apply("learner_fail_after", "10").unwrap();
    cfg.out_dir = out.to_string_lossy().into_owned();
    cfg.rl.checkpoint_every = 16; // post-failure saves are skipped, not fatal

    let (results, _agent, report) = run(&cfg, &SPECS4, 4, 2).unwrap();
    for r in &results {
        assert_eq!(r.episodes.len(), 66, "run did not complete after degradation");
        assert!(r.episodes.iter().all(|e| e.reward.is_finite()));
    }
    let rep = report.expect("off-loop learner always reports");
    let (at, why) = rep.degraded.clone().expect("degradation not surfaced");
    assert!((10..=12).contains(&at), "degraded at step {at}");
    assert!(why.contains("injected learner failure"), "{why}");
    assert!(rep.banner().contains("DEGRADED"), "{}", rep.banner());
    assert_eq!(rep.steps, 66, "every step absorbed (learner then inline tail)");
    let _ = std::fs::remove_dir_all(&out);
}

/// Fault-probe accounting (ISSUE 10 audit): the vec driver fires
/// EXACTLY three probes per lockstep step — A at the step boundary,
/// B after the env fan-out, C after the replay insert/queue send — so
/// 12 episodes × 1 wave = 36 probes. `crash_after=36` must still kill
/// the run (the last probe is not skipped) and `crash_after=37` must
/// never fire (no probe site double-counts), completing bit-identical
/// to a reference run with fault injection disarmed.
#[test]
fn probe_count_is_exactly_three_per_step() {
    let cfg = base_cfg(12);
    let reference = run(&cfg, &SPECS7, 2, 1).unwrap();

    let mut last = cfg.clone();
    last.rl.crash_after = 36;
    let err = run(&last, &SPECS7, 2, 1).unwrap_err();
    assert!(format!("{err:#}").contains(INJECTED_CRASH_MSG), "{err:#}");

    let mut past = cfg.clone();
    past.rl.crash_after = 37;
    let survived = run(&past, &SPECS7, 2, 1).unwrap();
    assert_run_matches(&reference, &survived, "armed-but-unfired fault counter");
}

/// The `Rng::{state, from_state}` round-trip carries the cached
/// Box-Muller spare: a generator restored mid-Gaussian-pair continues
/// the stream bit-identically, and a snapshot that dropped the spare
/// would demonstrably diverge — the skew a checkpoint codec bug would
/// introduce into every resumed exploration stream.
#[test]
fn rng_state_round_trip_carries_gaussian_spare() {
    use silicon_rl::util::rng::RngState;

    let mut a = Rng::new(0x5EED);
    let _ = a.gaussian(); // populate the spare (first of the pair)
    let snap = a.state();
    assert!(snap.gauss_spare.is_some(), "mid-pair snapshot lost the spare");

    let mut b = Rng::from_state(snap);
    for _ in 0..8 {
        assert_eq!(a.gaussian().to_bits(), b.gaussian().to_bits());
        assert_eq!(a.next_u64(), b.next_u64());
    }

    // dropping the spare is NOT equivalent: from the same snapshot, the
    // true restore serves the cached value while a spare-less restore
    // burns two fresh uniforms — both the value and the stream position
    // skew, which is exactly what a lossy checkpoint codec would cause
    let mut full = Rng::from_state(snap);
    let mut lossy = Rng::from_state(RngState { gauss_spare: None, ..snap });
    assert_ne!(full.gaussian().to_bits(), lossy.gaussian().to_bits());
    assert_ne!(
        full.next_u64(),
        lossy.next_u64(),
        "a spare-less restore should skew the stream; the codec must keep it"
    );
}

/// Atlas kill-and-resume on a reduced grid: checkpoints land at group
/// boundaries; a kill inside the second group resumes from the
/// first-group generation and reproduces statuses, per-point frontiers,
/// episode spend, the merged atlas and every lane's episode log
/// bit-identically. (Cache hit-rate counters are excluded — caches
/// restart cold by design and only their hit/miss tallies differ.)
#[test]
fn atlas_crash_resume_bit_identical_on_reduced_grid() {
    let mut cfg = RunConfig::default();
    cfg.backend = BackendSel::Native;
    cfg.artifacts_dir = "/nonexistent-artifacts".into();
    cfg.granularity = Granularity::Group;
    cfg.apply("nodes", "7").unwrap();
    cfg.apply("episodes", "10").unwrap();
    cfg.apply("warmup", "4").unwrap();
    cfg.apply("atlas_workloads", "smolvlm").unwrap();
    cfg.apply("atlas_phases", "decode").unwrap();
    cfg.apply("atlas_seq_lens", "512,2048").unwrap();
    cfg.apply("atlas_batches", "1").unwrap();
    cfg.apply("atlas_seeds", "1").unwrap();
    cfg.apply("atlas_prune", "off").unwrap(); // both points run in full
    let reference = rl::atlas::run(&cfg).unwrap();
    assert_eq!(reference.points.len(), 2);

    let out = tmp_out("atlas");
    let mut ccfg = cfg.clone();
    ccfg.out_dir = out.to_string_lossy().into_owned();
    ccfg.rl.checkpoint_every = 1; // any cadence >0 arms group-boundary saves
    // 10 steps × 3 probes per group: probe 35 is inside the second group,
    // after the first group's boundary checkpoint committed
    ccfg.rl.crash_after = 35;
    let err = rl::atlas::run(&ccfg).unwrap_err();
    assert!(format!("{err:#}").contains(INJECTED_CRASH_MSG), "{err:#}");

    let mut rcfg = ccfg.clone();
    rcfg.rl.crash_after = 0;
    rcfg.resume = Some(ccfg.out_dir.clone());
    let resumed = rl::atlas::run(&rcfg).unwrap();

    assert_eq!(reference.points.len(), resumed.points.len());
    for (p, q) in reference.points.iter().zip(&resumed.points) {
        let gi = p.grid_index;
        assert_eq!(gi, q.grid_index);
        assert_eq!(p.status.name(), q.status.name(), "point {gi}: status");
        assert_eq!(p.episodes, q.episodes, "point {gi}: episodes");
        let (fa, fb) = (p.frontier.frontier(), q.frontier.frontier());
        assert_eq!(fa.len(), fb.len(), "point {gi}: frontier size");
        for (x, y) in fa.iter().zip(fb) {
            assert_eq!(x.perf_gops.to_bits(), y.perf_gops.to_bits(), "point {gi}: perf");
            assert_eq!(x.power_mw.to_bits(), y.power_mw.to_bits(), "point {gi}: power");
            assert_eq!(x.area_mm2.to_bits(), y.area_mm2.to_bits(), "point {gi}: area");
            assert_eq!(x.episode, y.episode, "point {gi}: episode tag");
        }
    }
    let (rc, sc) = (&reference.counters, &resumed.counters);
    assert_eq!(rc.points, sc.points);
    assert_eq!(rc.solved, sc.solved);
    assert_eq!(rc.skipped, sc.skipped);
    assert_eq!(rc.shrunk, sc.shrunk);
    assert_eq!(rc.episodes_run, sc.episodes_run);
    assert_eq!(rc.episodes_budget, sc.episodes_budget);

    assert_eq!(reference.atlas.len(), resumed.atlas.len());
    for ((ka, va), (kb, vb)) in reference.atlas.iter().zip(&resumed.atlas) {
        assert_eq!(ka, kb);
        assert_eq!(va.len(), vb.len(), "merged atlas {ka:?}");
        for (x, y) in va.iter().zip(vb) {
            assert_eq!(x.perf_gops.to_bits(), y.perf_gops.to_bits(), "{ka:?}: perf");
            assert_eq!(x.power_mw.to_bits(), y.power_mw.to_bits(), "{ka:?}: power");
            assert_eq!(x.area_mm2.to_bits(), y.area_mm2.to_bits(), "{ka:?}: area");
        }
    }

    assert_eq!(reference.node_results.len(), resumed.node_results.len());
    for (lane, (a, b)) in
        reference.node_results.iter().zip(&resumed.node_results).enumerate()
    {
        assert_logs_identical(a, b, &format!("atlas lane {lane}"));
        assert_frontiers_identical(a, b, &format!("atlas lane {lane}"));
    }
    let _ = std::fs::remove_dir_all(&out);
}
