//! SIMD ↔ scalar parity suite (DESIGN.md §10) — the only test binary
//! that flips the process-global kernel path, so the simd-mode re-runs
//! of the golden contracts live here:
//!
//! 1. every f32 NN kernel matches the scalar reference within relative
//!    tolerance over randomized shapes, including ragged tails that are
//!    not multiples of the 8-wide (AVX2) / 4-wide (NEON) lanes;
//! 2. the vec-env lane-invariance contract (DESIGN.md §9) holds under
//!    `kernels=simd` — within one kernel mode, a B-lane vec run still
//!    matches B serial runs (asserted with tolerances: lane *bit*
//!    identity is a scalar-mode guarantee only);
//! 3. the staged evaluator's pruned ≡ exact argmax pin holds under
//!    `kernels=simd`, and the full evaluation pipeline is bitwise
//!    invariant to the kernel mode — SIMD never changes which design a
//!    search selects, because the f64 placement-scoring kernel is
//!    bit-identical to scalar by construction.
//!
//! Every test skips cleanly (with a note on stderr) on hosts without a
//! SIMD path, so CI on any machine runs the binary unconditionally.

use std::sync::Mutex;

use silicon_rl::config::{Granularity, RunConfig};
use silicon_rl::env::Action;
use silicon_rl::eval::{EvalOutcome, Evaluator};
use silicon_rl::nn::backend::{self, Backend, BackendSel};
use silicon_rl::nn::kernels::{self, KernelSel};
use silicon_rl::nn::math::{self, AdamStep};
use silicon_rl::rl::{self, run_node, LaneSpec, NodeResult, SacAgent};
use silicon_rl::util::Rng;

/// Serializes access to the process-global kernel path: cargo runs the
/// tests of this binary as threads of one process, so mode flips must
/// not overlap. Lib and other integration suites never flip the global
/// (see `nn::kernels`), which is why only this binary needs a lock.
static DISPATCH: Mutex<()> = Mutex::new(());

/// Runs `f` with the global kernel mode set to `sel`, then restores the
/// library default (scalar). Poisoning is tolerated: the next caller
/// re-installs its own mode before doing anything mode-dependent.
fn with_kernels<T>(sel: KernelSel, f: impl FnOnce() -> T) -> T {
    let _guard = DISPATCH.lock().unwrap_or_else(|e| e.into_inner());
    kernels::set_global(sel);
    let out = f();
    kernels::set_global(KernelSel::Scalar);
    out
}

/// `false` → no SIMD path on this host; the caller prints nothing else
/// and returns, so the suite is skip-clean on scalar-only machines.
fn has_simd(test: &str) -> bool {
    if kernels::detect().is_none() {
        eprintln!("{test}: no SIMD path detected on this host, skipping");
        return false;
    }
    true
}

fn assert_close(simd: &[f32], scalar: &[f32], tol: f32, what: &str) {
    assert_eq!(simd.len(), scalar.len(), "{what}: length");
    for (i, (&a, &e)) in simd.iter().zip(scalar).enumerate() {
        assert!(
            (a - e).abs() <= tol * (1.0 + e.abs()),
            "{what}[{i}]: simd {a} vs scalar {e}"
        );
    }
}

/// Uniform fill with ~1/8 exact zeros so the matmul zero-skip fast path
/// is exercised on both sides of the comparison.
fn fill(v: &mut [f32], rng: &mut Rng, lo: f64, hi: f64) {
    for x in v.iter_mut() {
        *x = if rng.below(8) == 0 { 0.0 } else { rng.uniform_in(lo, hi) as f32 };
    }
}

// ---------------------------------------------------------------- f32 kernels

#[test]
fn matmul_family_matches_scalar_over_ragged_shapes() {
    if !has_simd("matmul_family") {
        return;
    }
    let mut rng = Rng::new(0xD15);
    // the SAC hot-loop shapes, then randomized ragged ones straddling
    // the panel (64) and vector-lane (8/4) boundaries
    let mut shapes =
        vec![(1, 52, 256), (8, 52, 256), (64, 82, 256), (256, 256, 120), (3, 130, 5)];
    for _ in 0..8 {
        shapes.push((1 + rng.below(17), 1 + rng.below(131), 1 + rng.below(67)));
    }
    for (m, k, n) in shapes {
        let mut x = vec![0.0f32; m * k];
        let mut w = vec![0.0f32; k * n];
        let mut bias = vec![0.0f32; n];
        let mut dy = vec![0.0f32; m * n];
        fill(&mut x, &mut rng, -1.0, 1.0);
        fill(&mut w, &mut rng, -0.5, 0.5);
        fill(&mut bias, &mut rng, -0.2, 0.2);
        fill(&mut dy, &mut rng, -1.0, 1.0);

        let run = |sel: KernelSel| {
            let mut y = vec![0.0f32; m * n];
            let mut dx = vec![0.0f32; m * k];
            let mut dw = vec![0.0f32; k * n];
            let mut db = vec![0.0f32; n];
            with_kernels(sel, || {
                math::matmul_bias(&x, &w, &bias, &mut y, m, k, n);
                math::matmul_wt(&dy, &w, &mut dx, m, k, n);
                math::grad_w_b(&x, &dy, &mut dw, &mut db, m, k, n);
            });
            (y, dx, dw, db)
        };
        let (ys, dxs, dws, dbs) = run(KernelSel::Scalar);
        let (yv, dxv, dwv, dbv) = run(KernelSel::Simd);
        let what = format!("({m},{k},{n})");
        assert_close(&yv, &ys, 1e-4, &format!("matmul_bias {what}"));
        assert_close(&dxv, &dxs, 1e-4, &format!("matmul_wt {what}"));
        assert_close(&dwv, &dws, 1e-4, &format!("grad_w {what}"));
        assert_close(&dbv, &dbs, 1e-4, &format!("grad_b {what}"));
    }
}

#[test]
fn gelu_kernels_match_scalar_including_saturation_tails() {
    if !has_simd("gelu_kernels") {
        return;
    }
    let mut rng = Rng::new(0x6E1);
    for len in [1usize, 3, 8, 67, 256, 1000] {
        let mut z = vec![0.0f32; len];
        for (i, v) in z.iter_mut().enumerate() {
            // push deep into both tails so the clamped vector exp is hit
            *v = if i % 5 == 0 {
                rng.uniform_in(-12.0, 12.0) as f32
            } else {
                rng.uniform_in(-3.0, 3.0) as f32
            };
        }
        let mut g0 = vec![0.0f32; len];
        fill(&mut g0, &mut rng, -1.0, 1.0);

        let run = |sel: KernelSel| {
            let mut h = vec![0.0f32; len];
            let mut g = g0.clone();
            with_kernels(sel, || {
                math::gelu_map(&z, &mut h);
                math::gelu_bwd_inplace(&mut g, &z);
            });
            (h, g)
        };
        let (hs, gs) = run(KernelSel::Scalar);
        let (hv, gv) = run(KernelSel::Simd);
        assert_close(&hv, &hs, 2e-5, &format!("gelu_map len={len}"));
        assert_close(&gv, &gs, 2e-5, &format!("gelu_bwd len={len}"));
    }
}

#[test]
fn softmax_rows_matches_scalar_and_stays_normalized() {
    if !has_simd("softmax_rows") {
        return;
    }
    let mut rng = Rng::new(0x50F);
    for n in [1usize, 2, 4, 5, 8, 9, 20, 31] {
        let m = 7;
        let mut z0 = vec![0.0f32; m * n];
        fill(&mut z0, &mut rng, -8.0, 8.0);
        let run = |sel: KernelSel| {
            let mut z = z0.clone();
            with_kernels(sel, || math::softmax_rows(&mut z, n));
            z
        };
        let s = run(KernelSel::Scalar);
        let v = run(KernelSel::Simd);
        assert_close(&v, &s, 1e-5, &format!("softmax n={n}"));
        for r in 0..m {
            let sum: f32 = v[r * n..(r + 1) * n].iter().sum();
            assert!((sum - 1.0).abs() < 1e-4, "softmax n={n} row {r}: sum {sum}");
        }
    }
}

#[test]
fn adam_apply_matches_scalar_over_ragged_lengths() {
    if !has_simd("adam_apply") {
        return;
    }
    let mut rng = Rng::new(0xADA);
    for len in [1usize, 7, 8, 9, 64, 67, 1000] {
        for step in [1.0f64, 17.0] {
            let a = AdamStep::new(3e-4, 0.9, 0.999, 1e-8, step);
            let mut p0 = vec![0.0f32; len];
            let mut g = vec![0.0f32; len];
            let mut m0 = vec![0.0f32; len];
            let mut v0 = vec![0.0f32; len];
            fill(&mut p0, &mut rng, -1.0, 1.0);
            fill(&mut g, &mut rng, -0.5, 0.5);
            fill(&mut m0, &mut rng, -0.1, 0.1);
            for x in v0.iter_mut() {
                *x = rng.uniform_in(0.0, 1e-2) as f32;
            }
            let run = |sel: KernelSel| {
                let (mut p, mut m, mut v) = (p0.clone(), m0.clone(), v0.clone());
                with_kernels(sel, || a.apply(&mut p, &g, &mut m, &mut v));
                (p, m, v)
            };
            let (ps, ms, vs) = run(KernelSel::Scalar);
            let (pv, mv, vv) = run(KernelSel::Simd);
            let what = format!("adam len={len} step={step}");
            assert_close(&pv, &ps, 1e-5, &format!("{what}: p"));
            assert_close(&mv, &ms, 1e-5, &format!("{what}: m"));
            assert_close(&vv, &vs, 1e-5, &format!("{what}: v"));
        }
    }
}

// --------------------------------------------- vec-env contract under simd

fn rollout_cfg(episodes: usize) -> RunConfig {
    let mut cfg = RunConfig::default();
    cfg.backend = BackendSel::Native;
    cfg.artifacts_dir = "/nonexistent-artifacts".into();
    cfg.granularity = Granularity::Group;
    cfg.rl.episodes_per_node = episodes;
    cfg.rl.warmup_steps = 10_000; // rollout-only: updates never fire
    cfg
}

fn fresh_agent(cfg: &RunConfig) -> SacAgent {
    let be = backend::load(&cfg.artifacts_dir, cfg.backend).unwrap();
    assert_eq!(be.kind(), "native");
    SacAgent::new(be, cfg.rl, &mut Rng::new(42)).unwrap()
}

/// DESIGN.md §9 under `kernels=simd`: a 4-lane vec run vs 4 serial
/// `run_node` runs with the same seeds, all inside the simd mode. The
/// comparison uses tolerances — the bit-identity wording of the lane
/// contract is reserved for scalar mode (§10), even though the current
/// SIMD kernels happen to be batch-size-invariant per row.
#[test]
fn vec_lanes_match_serial_runs_under_simd() {
    if !has_simd("vec_lanes simd") {
        return;
    }
    let specs = [
        LaneSpec { nm: 7, seed: 7 },
        LaneSpec { nm: 28, seed: 42 },
        LaneSpec { nm: 7, seed: 13 },
        LaneSpec { nm: 28, seed: 99 },
    ];
    let cfg = rollout_cfg(8);
    let (vec_results, serials) = with_kernels(KernelSel::Simd, || {
        let mut vec_agent = fresh_agent(&cfg);
        let mut update_rng = Rng::new(cfg.seed).fork(0x0ECE);
        let vec_results =
            rl::run_vec(&cfg, &specs, &mut vec_agent, &mut update_rng, 4).unwrap();
        let serials: Vec<NodeResult> = specs
            .iter()
            .map(|spec| {
                let mut agent = fresh_agent(&cfg);
                let mut rng = Rng::new(spec.seed);
                run_node(&cfg, spec.nm, &mut agent, &mut rng).unwrap()
            })
            .collect();
        (vec_results, serials)
    });
    for (lane, (v, s)) in vec_results.iter().zip(&serials).enumerate() {
        let spec = &specs[lane];
        let what = format!("lane {lane} ({}nm seed {})", spec.nm, spec.seed);
        assert_eq!(v.episodes.len(), s.episodes.len(), "{what}: episode count");
        for (x, y) in v.episodes.iter().zip(&s.episodes) {
            let ep = x.episode;
            assert!(
                (x.reward - y.reward).abs() <= 1e-3 * (1.0 + y.reward.abs()),
                "{what} ep {ep}: reward {} vs {}",
                x.reward,
                y.reward
            );
            assert!(
                (x.score - y.score).abs() <= 1e-3 * (1.0 + y.score.abs()),
                "{what} ep {ep}: score {} vs {}",
                x.score,
                y.score
            );
        }
        assert_eq!(v.feasible_count, s.feasible_count, "{what}: feasible_count");
        assert_eq!(
            v.pareto.frontier().len(),
            s.pareto.frontier().len(),
            "{what}: frontier size"
        );
    }
}

// ------------------------------------------- evaluator contract under simd

fn small_cfg() -> RunConfig {
    let mut c = RunConfig::default();
    c.granularity = Granularity::Group;
    c
}

fn random_action(rng: &mut Rng) -> Action {
    let mut a = Action::neutral();
    for v in a.cont.iter_mut() {
        *v = rng.uniform_in(-1.0, 1.0);
    }
    for d in a.deltas.iter_mut() {
        *d = rng.below(5) as i32 - 2;
    }
    a
}

fn assert_outcomes_identical(a: &EvalOutcome, b: &EvalOutcome, what: &str) {
    assert_eq!(a.reward.total.to_bits(), b.reward.total.to_bits(), "{what}: reward");
    assert_eq!(a.reward.score.to_bits(), b.reward.score.to_bits(), "{what}: score");
    assert_eq!(a.reward.feasible, b.reward.feasible, "{what}: feasible");
    assert_eq!(
        a.ppa.tokens_per_s.to_bits(),
        b.ppa.tokens_per_s.to_bits(),
        "{what}: tokens/s"
    );
    assert_eq!(a.decoded.mesh, b.decoded.mesh, "{what}: mesh");
    for (i, (x, y)) in a.full_state.iter().zip(&b.full_state).enumerate() {
        assert_eq!(x.to_bits(), y.to_bits(), "{what}: state dim {i}");
    }
}

/// The eval_staged golden sweep, re-run in simd mode: the pruned batch
/// argmax still selects a bit-identical outcome to the exact scan at
/// any worker count (valid bitwise even under SIMD because the f64
/// placement-scoring kernel reproduces scalar exactly).
#[test]
fn pruned_batch_argmax_bit_identical_to_exact_under_simd() {
    if !has_simd("pruned argmax simd") {
        return;
    }
    let cfg = small_cfg();
    with_kernels(KernelSel::Simd, || {
        for nm in [3u32, 7, 28] {
            let ev = Evaluator::new(&cfg, nm);
            let mut mesh = ev.initial_mesh();
            let mut rng = Rng::new(40 + nm as u64);
            for round in 0..2 {
                let actions: Vec<Action> =
                    (0..8).map(|_| random_action(&mut rng)).collect();
                let exact = ev.evaluate_best(&mesh, &actions, 1, false);
                for threads in [1usize, 4] {
                    let pruned = ev.evaluate_best(&mesh, &actions, threads, true);
                    assert_eq!(
                        exact.best, pruned.best,
                        "{nm}nm round {round}, {threads} threads: selection diverged"
                    );
                    assert_outcomes_identical(
                        exact.best_outcome(),
                        pruned.best_outcome(),
                        &format!("{nm}nm round {round}, {threads} threads"),
                    );
                }
                mesh = exact.best_outcome().decoded.mesh;
            }
        }
    });
}

/// The design-preservation pin of the tentpole: the analytical
/// evaluator is f64-only, and its one dispatched kernel
/// (`MeshGeom::score_tiles`) is bit-identical across paths, so the full
/// pipeline — and therefore every selected design — must be bitwise
/// invariant to the kernel mode.
#[test]
fn evaluation_outcomes_bit_identical_across_kernel_modes() {
    if !has_simd("eval cross-mode") {
        return;
    }
    let cfg = small_cfg();
    for nm in [3u32, 7, 14, 28] {
        let ev = Evaluator::new(&cfg, nm);
        let mut mesh = ev.initial_mesh();
        let mut rng = Rng::new(1000 + nm as u64);
        for round in 0..3 {
            let actions: Vec<Action> = (0..6).map(|_| random_action(&mut rng)).collect();
            let scalar =
                with_kernels(KernelSel::Scalar, || ev.evaluate_best(&mesh, &actions, 2, true));
            let simd =
                with_kernels(KernelSel::Simd, || ev.evaluate_best(&mesh, &actions, 2, true));
            assert_eq!(
                scalar.best, simd.best,
                "{nm}nm round {round}: selected design diverged across kernel modes"
            );
            assert_outcomes_identical(
                scalar.best_outcome(),
                simd.best_outcome(),
                &format!("{nm}nm round {round}"),
            );
            mesh = scalar.best_outcome().decoded.mesh;
        }
    }
}
