//! Workload-layer contract suite:
//!
//! 1. **Property suite over every registry entry** — graph
//!    well-formedness (inputs precede their op / acyclic by topological
//!    id order, finite costs) and agreement with the spec's closed
//!    forms (op count, weight-tensor count, instruction total, parameter
//!    count, interface tensors).
//! 2. **Golden pins** — the spec-built Llama 3.1 8B and SmolVLM graphs
//!    reproduce the removed hand-rolled builders' paper statistics
//!    exactly (Table 8/9: 7,489 ops / 291 tensors / 597 M instrs /
//!    14.96 GB; SmolVLM: 1,488 ops / 286 tensors / 0.48 GB / 62/61
//!    interfaces), plus per-op structural invariants the old builders
//!    guaranteed.
//! 3. **Scenario axis** — phase/seq_len/batch reach the graph, the KV
//!    footprint and the throughput model, and salt the evaluation
//!    caches.

use silicon_rl::config::RunConfig;
use silicon_rl::env::Action;
use silicon_rl::eval::{EvalScratch, Evaluator};
use silicon_rl::ir::spec::{Phase, Scenario};
use silicon_rl::ir::{registry, stats, OpKind};
use silicon_rl::partition::groups::units_from_groups;

#[test]
fn every_registry_entry_builds_a_well_formed_graph() {
    for spec in registry::all() {
        let g = spec.build_default();
        // structural invariants: topological edges, finite costs
        g.validate().unwrap_or_else(|e| panic!("{}: {e}", spec.name));
        // every non-source op has at least one input; sources are the
        // graph's ids/image feeds
        let sources = g.ops.iter().filter(|o| o.inputs.is_empty()).count();
        assert!(
            (1..=2).contains(&sources),
            "{}: {sources} source ops",
            spec.name
        );
        // closed-form totals match the built graph exactly
        assert_eq!(g.ops.len(), spec.expected_ops(), "{}: op count", spec.name);
        assert_eq!(
            g.weight_tensors,
            spec.expected_weight_tensors(),
            "{}: weight tensors",
            spec.name
        );
        let instrs = g.total_instrs();
        let expect = spec.expected_instrs();
        assert!(
            (instrs - expect).abs() / expect < 1e-6,
            "{}: instrs {instrs} vs closed form {expect}",
            spec.name
        );
        let w = g.total_weight_bytes();
        let we = spec.expected_weight_bytes();
        assert!(
            (w - we).abs() / we < 1e-9,
            "{}: weights {w} vs closed form {we}",
            spec.name
        );
        assert!(
            (g.params - spec.expected_params()).abs() / spec.expected_params() < 1e-9,
            "{}: params",
            spec.name
        );
        assert_eq!(
            (g.n_inputs, g.n_outputs),
            spec.interface_tensors(),
            "{}: interface tensors",
            spec.name
        );
        // workload statistics stay finite and sane
        let s = stats::compute(&g);
        assert!(s.ilp.is_finite() && s.ilp > 1.0, "{}: ilp {}", spec.name, s.ilp);
        assert!(
            (0.0..=1.0).contains(&s.matmul_ratio),
            "{}: matmul ratio",
            spec.name
        );
        assert!(
            s.matmul_ratio > 0.5,
            "{}: transformers are matmul-dominated ({})",
            spec.name,
            s.matmul_ratio
        );
        // the graph-summed FLOPs track the 2·P·φ model within 2x
        let ratio = g.total_flops_per_token() / g.flops_per_token_model();
        assert!(
            (0.5..=2.0).contains(&ratio),
            "{}: flops ratio {ratio}",
            spec.name
        );
    }
}

#[test]
fn every_registry_entry_groups_and_preserves_totals() {
    for spec in registry::all() {
        let g = spec.build_default();
        let units = units_from_groups(&g);
        assert!(!units.is_empty(), "{}", spec.name);
        let uf: f64 = units.iter().map(|u| u.flops).sum();
        let uw: f64 = units.iter().map(|u| u.weight_bytes).sum();
        assert!(
            (uf - g.total_flops_per_token()).abs() / uf.max(1.0) < 1e-9,
            "{}: grouped flops drift",
            spec.name
        );
        assert!(
            (uw - g.total_weight_bytes()).abs() / uw.max(1.0) < 1e-9,
            "{}: grouped weights drift",
            spec.name
        );
        // grouped units stay topologically ordered
        for (i, u) in units.iter().enumerate() {
            for &p in &u.inputs {
                assert!((p as usize) < i, "{}: unit edge order", spec.name);
            }
        }
    }
}

#[test]
fn golden_llama_pins_table8_and_9() {
    let g = silicon_rl::ir::llama::build();
    assert_eq!(g.ops.len(), 7489);
    assert_eq!(g.weight_tensors, 291);
    assert!((g.total_instrs() - 597e6).abs() / 597e6 < 1e-6);
    let gb = g.total_weight_bytes() / (1u64 << 30) as f64;
    assert!((gb - 14.96).abs() < 0.05, "weights {gb} GiB");
    assert!((g.params / 1e9 - 8.03).abs() < 0.03);
    assert_eq!((g.n_inputs, g.n_outputs), (66, 65));
    let kv = g.kv.unwrap();
    assert_eq!(
        (kv.n_layers, kv.n_kv_heads, kv.head_dim, kv.elem_bytes),
        (32, 8, 128, 2)
    );
    // per-layer structure of the hand-rolled builder: 233 ops per layer
    for layer in 0..32 {
        let n = g.ops.iter().filter(|o| o.layer == layer).count();
        assert_eq!(n, 233, "layer {layer}");
    }
    // 33 global ops (2 prologue + 31 epilogue)
    assert_eq!(g.ops.iter().filter(|o| o.layer == -1).count(), 33);
    // 9 weight tensors per layer
    for layer in 0..32 {
        let n = g
            .ops
            .iter()
            .filter(|o| o.layer == layer && o.weight_bytes > 0.0)
            .count();
        assert_eq!(n, 9, "layer {layer} weight tensors");
    }
    // critical path in the hand-rolled builder's range
    let cp = stats::critical_path_len(&g);
    assert!(cp > 500 && cp < 7489, "critical path {cp}");
}

#[test]
fn golden_smolvlm_pins() {
    let g = silicon_rl::ir::smolvlm::build();
    // op-for-op equivalents of the removed hand-rolled builder:
    //   vision: img + conv + 12×23 + proj = 279
    //   text:   ids + embed + fuse + 30×40 + head + softmax(5) = 1,209
    assert_eq!(g.ops.len(), 1488);
    // conv + 12×6 vit + proj + embed + 30×7 dec + head = 286
    assert_eq!(g.weight_tensors, 286);
    let gb = g.total_weight_bytes() / (1u64 << 30) as f64;
    assert!((gb - 0.48).abs() < 0.08, "weights {gb} GiB");
    assert_eq!((g.n_inputs, g.n_outputs), (62, 61));
    // instruction model: 20/op floor + 12M budget
    let expect = 20.0 * 1488.0 + 12e6;
    assert!((g.total_instrs() - expect).abs() / expect < 1e-9);
    let kv = g.kv.unwrap();
    assert_eq!(
        (kv.n_layers, kv.n_kv_heads, kv.head_dim, kv.elem_bytes),
        (30, 3, 64, 2)
    );
    // vision tower present: conv + per-vit-layer 23 ops at layers 0..12
    assert!(g.ops.iter().any(|o| o.kind == OpKind::Conv));
    for layer in 0..12 {
        assert_eq!(
            g.ops.iter().filter(|o| o.layer == layer).count(),
            23,
            "vit layer {layer}"
        );
    }
    // decoder layers at 100.. with 40 ops each
    for layer in 100..130 {
        assert_eq!(
            g.ops.iter().filter(|o| o.layer == layer).count(),
            40,
            "decoder layer {layer}"
        );
    }
}

#[test]
fn scenario_reaches_kv_footprint_and_memory_ceiling() {
    // longer context ⇒ bigger resident KV ⇒ more per-tile KV bytes
    let mut short = RunConfig::default();
    short.apply("seq_len", "1024").unwrap();
    let mut long = RunConfig::default();
    long.apply("seq_len", "8192").unwrap();
    let ev_s = Evaluator::new(&short, 7);
    let ev_l = Evaluator::new(&long, 7);
    let mesh = ev_s.initial_mesh();
    let a = Action::neutral();
    let (ds, _) = ev_s.stage_decode(&mesh, &a);
    let (dl, _) = ev_l.stage_decode(&mesh, &a);
    let mut scratch = EvalScratch::default();
    let ps = ev_s.stage_place(&ds, &mut scratch);
    let pl = ev_l.stage_place(&dl, &mut scratch);
    let kv_s: f64 = ps.loads.iter().map(|l| l.kv_bytes).sum();
    let kv_l: f64 = pl.loads.iter().map(|l| l.kv_bytes).sum();
    assert!(
        kv_l > 4.0 * kv_s,
        "8K context must hold ≥4x the KV of 1K: {kv_s} vs {kv_l}"
    );

    // a bigger batch amortizes the weight sweep ⇒ higher memory ceiling
    let mut b1 = RunConfig::default();
    b1.apply("batch", "1").unwrap();
    let mut b4 = RunConfig::default();
    b4.apply("batch", "4").unwrap();
    let o1 = Evaluator::new(&b1, 7).evaluate(&mesh, &a, &mut EvalScratch::default());
    let o4 = Evaluator::new(&b4, 7).evaluate(&mesh, &a, &mut EvalScratch::default());
    assert!(o4.ppa.ceilings.memory > o1.ppa.ceilings.memory);
}

#[test]
fn prefill_graph_differs_and_admission_stays_admissible() {
    let mut cfg = RunConfig::default();
    cfg.apply("phase", "prefill").unwrap();
    let ev = Evaluator::new(&cfg, 3);
    assert_eq!(ev.scenario.phase, Phase::Prefill);
    // the admission bound must stay sound under the scenario axis
    let mesh = ev.initial_mesh();
    let mut scratch = EvalScratch::default();
    for i in 0..6 {
        let mut a = Action::neutral();
        a.cont[2] = -1.0 + 0.4 * i as f64;
        a.cont[19] = 0.3;
        let (d, _) = ev.stage_decode(&mesh, &a);
        let bound = ev.admission_bound(&d);
        let out = ev.evaluate(&mesh, &a, &mut scratch);
        assert!(
            bound <= out.reward.score + 1e-9,
            "prefill bound {bound} exceeds score {}",
            out.reward.score
        );
    }
}

#[test]
fn encoder_has_no_prompt_axis_to_amortize() {
    // an image encoder has no prefill pass: phase=prefill must not
    // inflate the Eq 22 memory ceiling by a phantom seq_len amortization
    let mut dec = RunConfig::default();
    dec.apply("workload", "vit-base").unwrap();
    dec.apply("batch", "1").unwrap();
    let mut pre = dec.clone();
    pre.apply("phase", "prefill").unwrap();
    pre.apply("seq_len", "8192").unwrap();
    let ev_d = Evaluator::new(&dec, 7);
    let ev_p = Evaluator::new(&pre, 7);
    let mesh = ev_d.initial_mesh();
    let a = Action::neutral();
    let od = ev_d.evaluate(&mesh, &a, &mut EvalScratch::default());
    let op = ev_p.evaluate(&mesh, &a, &mut EvalScratch::default());
    assert_eq!(
        od.ppa.ceilings.memory.to_bits(),
        op.ppa.ceilings.memory.to_bits(),
        "encoder memory ceiling must be phase-independent"
    );
}

#[test]
fn batch_salts_eval_but_not_placement() {
    // batch does not reach the (pre-KV) placement: same units, same
    // placement key — but the whole-outcome salt must differ
    let mut b1 = RunConfig::default();
    b1.apply("batch", "1").unwrap();
    let mut b4 = RunConfig::default();
    b4.apply("batch", "4").unwrap();
    let e1 = Evaluator::new(&b1, 3);
    let e4 = Evaluator::new(&b4, 3);
    assert_ne!(e1.eval_salt(), e4.eval_salt());
    let mesh = e1.initial_mesh();
    let a = Action::neutral();
    let mut shared = EvalScratch::default();
    let (d1, _) = e1.stage_decode(&mesh, &a);
    let (d4, _) = e4.stage_decode(&mesh, &a);
    e1.stage_place(&d1, &mut shared);
    let misses = shared.stages.misses;
    e4.stage_place(&d4, &mut shared);
    assert_eq!(
        shared.stages.misses, misses,
        "identical units must replay placement across batch scenarios"
    );
    assert!(shared.stages.hits > 0);
}

#[test]
fn default_batch_is_3_for_llama_and_1_for_smolvlm() {
    // the former hardcoded `batch_size: 3` is now the Llama Table 9
    // default; the low-power SmolVLM profile serves a single sequence
    let hp = RunConfig::default();
    assert_eq!(Evaluator::new(&hp, 3).batch_size(), 3);
    let lp = RunConfig::smolvlm_low_power();
    assert_eq!(Evaluator::new(&lp, 3).batch_size(), 1);
}

#[test]
fn scenario_spec_defaults_round_trip() {
    for spec in registry::all() {
        let scn = spec.default_scenario();
        assert_eq!(scn.phase, Phase::Decode);
        assert_eq!(scn.seq_len, spec.default_seq_len);
        let g = spec.build(&scn);
        assert_eq!(g.scenario, scn);
        // prefill builds too, with φ at the prefill value
        let pre = Scenario { phase: Phase::Prefill, ..scn };
        let gp = spec.build(&pre);
        assert_eq!(gp.phi, spec.phi_prefill);
        assert_eq!(gp.ops.len(), g.ops.len());
    }
}
