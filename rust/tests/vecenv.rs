//! Vec-env golden suite: the lane determinism contract of DESIGN.md §9.
//!
//! * A B-lane vectorized run (batched actor forwards, parallel env
//!   fan-out, lane-major replay) with updates disabled is bit-identical
//!   per lane — episode logs, Pareto frontier, replay contents — to B
//!   serial `run_node` runs with the same per-lane seeds.
//! * The merged Pareto frontier is invariant to the vec width (how jobs
//!   are grouped into waves) and to the worker-thread count.
//! * The batched native forward is bitwise batch-invariant (the f32
//!   accumulation-order audit behind the contract).
//! * With live updates the engine is still seed-deterministic.
//! * Native ↔ PJRT batched rollouts agree within tolerance when AOT
//!   artifacts and the PJRT runtime exist (skips cleanly otherwise).

use std::path::Path;

use silicon_rl::config::{Granularity, RunConfig};
use silicon_rl::env::{ACT_DIM, DISC_DIM, SAC_STATE_DIM};
use silicon_rl::nn::backend::{self, Backend, BackendSel};
use silicon_rl::rl::{self, run_node, LaneDecision, LaneSpec, NodeResult, SacAgent};
use silicon_rl::runtime;
use silicon_rl::util::stats::RunningStat;
use silicon_rl::util::Rng;

/// Lane jobs of the golden contract: 8 lanes — the required seeds
/// {7, 42} at 7nm and 28nm, plus two more seeds per node so the
/// acceptance shape (lanes=8 vs 8 serial runs) is pinned exactly.
const GOLDEN_SPECS: [LaneSpec; 8] = [
    LaneSpec { nm: 7, seed: 7 },
    LaneSpec { nm: 7, seed: 42 },
    LaneSpec { nm: 28, seed: 7 },
    LaneSpec { nm: 28, seed: 42 },
    LaneSpec { nm: 7, seed: 13 },
    LaneSpec { nm: 28, seed: 13 },
    LaneSpec { nm: 7, seed: 99 },
    LaneSpec { nm: 28, seed: 99 },
];

fn rollout_cfg(episodes: usize) -> RunConfig {
    let mut cfg = RunConfig::default();
    cfg.backend = BackendSel::Native;
    cfg.artifacts_dir = "/nonexistent-artifacts".into();
    cfg.granularity = Granularity::Group;
    cfg.rl.episodes_per_node = episodes;
    cfg.rl.warmup_steps = 10_000; // rollout-only: updates never fire
    cfg
}

/// Fresh agent with the pinned seed-42 store init (the same init every
/// serial reference run uses, so shared-store reads are identical).
fn fresh_agent(cfg: &RunConfig) -> SacAgent {
    let be = backend::load(&cfg.artifacts_dir, cfg.backend).unwrap();
    assert_eq!(be.kind(), "native");
    SacAgent::new(be, cfg.rl, &mut Rng::new(42)).unwrap()
}

fn assert_logs_identical(a: &NodeResult, b: &NodeResult, what: &str) {
    assert_eq!(a.episodes.len(), b.episodes.len(), "{what}: episode count");
    for (x, y) in a.episodes.iter().zip(&b.episodes) {
        let ep = x.episode;
        assert_eq!(x.reward.to_bits(), y.reward.to_bits(), "{what} ep {ep}: reward");
        assert_eq!(x.score.to_bits(), y.score.to_bits(), "{what} ep {ep}: score");
        assert_eq!(
            x.best_score.to_bits(),
            y.best_score.to_bits(),
            "{what} ep {ep}: best_score"
        );
        assert_eq!(x.feasible, y.feasible, "{what} ep {ep}: feasible");
        assert_eq!(x.eps.to_bits(), y.eps.to_bits(), "{what} ep {ep}: eps");
        assert_eq!(x.entropy.to_bits(), y.entropy.to_bits(), "{what} ep {ep}: entropy");
        assert_eq!((x.mesh_w, x.mesh_h), (y.mesh_w, y.mesh_h), "{what} ep {ep}: mesh");
        assert_eq!(x.unique_configs, y.unique_configs, "{what} ep {ep}: unique");
    }
    assert_eq!(a.feasible_count, b.feasible_count, "{what}: feasible_count");
}

fn assert_frontiers_identical(a: &NodeResult, b: &NodeResult, what: &str) {
    let (fa, fb) = (a.pareto.frontier(), b.pareto.frontier());
    assert_eq!(fa.len(), fb.len(), "{what}: frontier size");
    for (p, q) in fa.iter().zip(fb) {
        assert_eq!(p.perf_gops.to_bits(), q.perf_gops.to_bits(), "{what}: perf");
        assert_eq!(p.power_mw.to_bits(), q.power_mw.to_bits(), "{what}: power");
        assert_eq!(p.area_mm2.to_bits(), q.area_mm2.to_bits(), "{what}: area");
        assert_eq!(p.episode, q.episode, "{what}: episode tag");
    }
}

/// (a) of the golden suite: a `lanes=8` vec run ≡ 8 serial `run_node`
/// runs with the same seeds — per-lane episode logs + Pareto frontiers
/// bit-identical and the shared replay buffer the exact lane-major
/// interleaving of the serial runs'.
#[test]
fn vec_lanes_bit_identical_to_serial_runs() {
    let cfg = rollout_cfg(10);
    let b = GOLDEN_SPECS.len();
    assert_eq!(b, 8, "acceptance shape: 8 lanes vs 8 serial runs");

    let mut vec_agent = fresh_agent(&cfg);
    let mut update_rng = Rng::new(cfg.seed).fork(0x0ECE);
    let vec_results =
        rl::run_vec(&cfg, &GOLDEN_SPECS, &mut vec_agent, &mut update_rng, 4).unwrap();

    for (lane, spec) in GOLDEN_SPECS.iter().enumerate() {
        let mut agent = fresh_agent(&cfg);
        let mut rng = Rng::new(spec.seed);
        let serial = run_node(&cfg, spec.nm, &mut agent, &mut rng).unwrap();
        let what = format!("lane {lane} ({}nm seed {})", spec.nm, spec.seed);
        assert_logs_identical(&vec_results[lane], &serial, &what);
        assert_frontiers_identical(&vec_results[lane], &serial, &what);

        // replay contents: vec slot t·B+lane == serial slot t, every field
        assert_eq!(agent.buffer.len(), cfg.rl.episodes_per_node);
        for t in 0..cfg.rl.episodes_per_node {
            let v = vec_agent.buffer.get(t * b + lane);
            let s = agent.buffer.get(t);
            assert_eq!(v.r.to_bits(), s.r.to_bits(), "{what} t {t}: reward");
            assert_eq!(v.done.to_bits(), s.done.to_bits(), "{what} t {t}: done");
            for j in 0..SAC_STATE_DIM {
                assert_eq!(v.s[j].to_bits(), s.s[j].to_bits(), "{what} t {t}: s[{j}]");
                assert_eq!(v.s2[j].to_bits(), s.s2[j].to_bits(), "{what} t {t}: s2[{j}]");
            }
            for j in 0..ACT_DIM {
                assert_eq!(
                    v.a_cont[j].to_bits(),
                    s.a_cont[j].to_bits(),
                    "{what} t {t}: a[{j}]"
                );
            }
            assert_eq!(v.a_disc, s.a_disc, "{what} t {t}: a_disc");
            for j in 0..3 {
                assert_eq!(
                    v.ppa[j].to_bits(),
                    s.ppa[j].to_bits(),
                    "{what} t {t}: ppa[{j}]"
                );
            }
        }
    }
    assert_eq!(vec_agent.buffer.len(), b * cfg.rl.episodes_per_node);
}

/// (b) of the golden suite: the merged Pareto frontier — and the
/// lane-major reward running stats — are invariant to the vec width
/// (wave grouping) and to the worker-thread count.
#[test]
fn merged_frontier_invariant_to_lane_count_and_threads() {
    let cfg = rollout_cfg(8);

    let run = |lanes: usize, threads: usize| -> (Vec<NodeResult>, RunningStat) {
        let mut agent = fresh_agent(&cfg);
        let results =
            rl::run_jobs(&cfg, &GOLDEN_SPECS, lanes, &mut agent, threads).unwrap();
        let stats = rl::vecenv::reward_stats(&results);
        (results, stats)
    };

    let (base, base_stats) = run(4, 4);
    for (lanes, threads) in [(1usize, 1usize), (2, 4), (3, 2), (4, 1), (8, 4)] {
        let (got, got_stats) = run(lanes, threads);
        let what = format!("lanes={lanes} threads={threads}");
        // per-job identity implies merged-frontier identity; check both
        let mut merged_base = rl::ParetoArchive::new();
        let mut merged_got = rl::ParetoArchive::new();
        for (b, g) in base.iter().zip(&got) {
            assert_logs_identical(g, b, &what);
            assert_frontiers_identical(g, b, &what);
            merged_base.merge(&b.pareto);
            merged_got.merge(&g.pareto);
        }
        assert_eq!(merged_got.len(), merged_base.len(), "{what}: merged frontier");
        // f64 lane-major accumulation: aggregates match to the bit
        assert_eq!(got_stats.count(), base_stats.count(), "{what}: stat count");
        assert_eq!(
            got_stats.mean().to_bits(),
            base_stats.mean().to_bits(),
            "{what}: reward mean"
        );
        assert_eq!(
            got_stats.std().to_bits(),
            base_stats.std().to_bits(),
            "{what}: reward std"
        );
    }
}

/// The f32 accumulation-order audit behind the contract: every row of a
/// batched native actor forward is bitwise identical to a B=1 forward of
/// that row — batching can never perturb a lane's policy.
#[test]
fn batched_actor_forward_is_bitwise_batch_invariant() {
    let cfg = rollout_cfg(1);
    let mut agent = fresh_agent(&cfg);
    let b = 8usize;
    let states: Vec<f32> = (0..b * SAC_STATE_DIM)
        .map(|j| ((j * 37 % 23) as f32 - 11.0) / 12.0)
        .collect();

    // batched pass: copy the outputs out of the backend scratch
    let (mu_b, ls_b, dl_b) = {
        let out = agent.backend.actor_fwd(&agent.store, &states).unwrap();
        (out.mu.to_vec(), out.log_std.to_vec(), out.disc_logits.to_vec())
    };
    assert_eq!(mu_b.len(), b * ACT_DIM);

    for i in 0..b {
        let row = &states[i * SAC_STATE_DIM..(i + 1) * SAC_STATE_DIM];
        let out = agent.backend.actor_fwd(&agent.store, row).unwrap();
        for j in 0..ACT_DIM {
            assert_eq!(
                out.mu[j].to_bits(),
                mu_b[i * ACT_DIM + j].to_bits(),
                "row {i} mu[{j}]"
            );
            assert_eq!(
                out.log_std[j].to_bits(),
                ls_b[i * ACT_DIM + j].to_bits(),
                "row {i} log_std[{j}]"
            );
        }
        for j in 0..DISC_DIM {
            assert_eq!(
                out.disc_logits[j].to_bits(),
                dl_b[i * DISC_DIM + j].to_bits(),
                "row {i} dl[{j}]"
            );
        }
    }
}

/// `act_lanes` (batched selection) produces the same actions and entropy
/// as per-lane `act` calls with identically-seeded RNGs.
#[test]
fn act_lanes_matches_per_lane_act() {
    let cfg = rollout_cfg(1);
    let mut agent = fresh_agent(&cfg);
    let b = 3usize;
    let states: Vec<f32> = (0..b * SAC_STATE_DIM)
        .map(|j| ((j * 13 % 17) as f32 - 8.0) / 9.0)
        .collect();
    let decisions = vec![LaneDecision { explore: false }; b];
    let mut rngs: Vec<Rng> = (0..b).map(|i| Rng::new(100 + i as u64)).collect();
    let picked = agent.act_lanes(&states, &decisions, &mut rngs).unwrap();

    for i in 0..b {
        let mut s = [0.0f32; SAC_STATE_DIM];
        s.copy_from_slice(&states[i * SAC_STATE_DIM..(i + 1) * SAC_STATE_DIM]);
        let mut rng = Rng::new(100 + i as u64);
        let serial = agent.act(&s, true, &mut rng).unwrap();
        let (action, entropy) = &picked[i];
        for j in 0..ACT_DIM {
            assert_eq!(
                action.cont[j].to_bits(),
                serial.cont[j].to_bits(),
                "lane {i} cont[{j}]"
            );
        }
        assert_eq!(action.deltas, serial.deltas, "lane {i} deltas");
        assert_eq!(
            entropy.unwrap().to_bits(),
            agent.last_entropy.to_bits(),
            "lane {i} entropy"
        );
    }
}

/// With live updates (shared buffer + amortized update cadence) the
/// engine is still fully deterministic from `(cfg.seed, lane seeds)`:
/// two identical runs agree to the bit, for any worker count.
#[test]
fn live_update_vec_run_is_seed_deterministic() {
    // warmup 8 → the effective gate is max(8, minibatch=256): with 4
    // lanes the buffer crosses 256 at step 64, so the last steps run live
    // SAC + wm + sur updates (and, once the world model trains, the MPC
    // planner with real re-ranking)
    let mut cfg = rollout_cfg(66);
    cfg.rl.warmup_steps = 8;
    let specs = [
        LaneSpec { nm: 7, seed: 7 },
        LaneSpec { nm: 7, seed: 42 },
        LaneSpec { nm: 28, seed: 7 },
        LaneSpec { nm: 28, seed: 42 },
    ];
    let run = |threads: usize| {
        let mut agent = fresh_agent(&cfg);
        let results = rl::run_jobs(&cfg, &specs, specs.len(), &mut agent, threads)
            .unwrap();
        (results, agent.updates_done)
    };
    let (r1, u1) = run(4);
    let (r2, u2) = run(1);
    assert!(u1 > 0, "updates never fired");
    assert_eq!(u1, u2, "update count diverged");
    for (lane, (a, b)) in r1.iter().zip(&r2).enumerate() {
        assert_logs_identical(a, b, &format!("live lane {lane}"));
        assert_frontiers_identical(a, b, &format!("live lane {lane}"));
    }
}

/// (c) of the golden suite: batched rollouts over native vs PJRT agree
/// within tolerance (XLA accumulates f32 in a different order). Gated on
/// built artifacts + a linked PJRT runtime; skips cleanly otherwise.
#[test]
fn native_pjrt_batched_rollout_parity_when_available() {
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !dir.join("manifest.json").exists() || !runtime::backend_available() {
        eprintln!("vecenv parity: artifacts or PJRT unavailable; skipping");
        return;
    }
    let mut cfg = rollout_cfg(6);
    cfg.artifacts_dir = dir.to_string_lossy().to_string();
    let specs = [LaneSpec { nm: 7, seed: 7 }, LaneSpec { nm: 28, seed: 42 }];

    // native: both lanes batched through one vec-env. PJRT: one lane per
    // run (the lowered HLO only bakes B ∈ {1, mpc_batch, batch} actor
    // entrypoints), so this also crosses the batching axis.
    let native = {
        let be = backend::load(&cfg.artifacts_dir, BackendSel::Native).unwrap();
        let mut agent = SacAgent::new(be, cfg.rl, &mut Rng::new(42)).unwrap();
        let mut update_rng = Rng::new(cfg.seed).fork(0x0ECE);
        rl::run_vec(&cfg, &specs, &mut agent, &mut update_rng, 2).unwrap()
    };
    let pjrt: Vec<NodeResult> = specs
        .iter()
        .map(|sp| {
            let be = backend::load(&cfg.artifacts_dir, BackendSel::Pjrt).unwrap();
            let mut agent = SacAgent::new(be, cfg.rl, &mut Rng::new(42)).unwrap();
            let mut update_rng = Rng::new(cfg.seed).fork(0x0ECE);
            rl::run_vec(&cfg, &[*sp], &mut agent, &mut update_rng, 1)
                .unwrap()
                .remove(0)
        })
        .collect();
    for (lane, (n, p)) in native.iter().zip(&pjrt).enumerate() {
        assert_eq!(n.episodes.len(), p.episodes.len());
        for (x, y) in n.episodes.iter().zip(&p.episodes) {
            // rewards flow through the analytical evaluator (f64); only
            // the f32 policy path differs across backends
            assert!(
                (x.reward - y.reward).abs() <= 1e-3 * (1.0 + x.reward.abs()),
                "lane {lane} ep {}: native {} pjrt {}",
                x.episode,
                x.reward,
                y.reward
            );
            assert!((x.entropy - y.entropy).abs() <= 1e-2, "lane {lane} entropy");
        }
    }
}
