//! Atlas sweep contract suite (DESIGN.md §12).
//!
//! * `atlas_prune=on` is lossless for every point it does NOT skip: the
//!   pruned sweep's per-point frontiers are bit-identical to the exact
//!   (`atlas_prune=off`) sweep's.
//! * Every skipped point is *verifiably* covered: each point of its
//!   exact frontier is weakly dominated in (perf ↑, energy mJ/token ↓,
//!   area ↓) space by the justifying neighbor's achieved frontier.
//! * Warm mode populates the process-wide shared cache with per-salt
//!   occupancy evidence.
//!
//! The power budget is raised far above any achievable design so power
//! never binds: with batch-invariant decode/projection and shared
//! batch-axis action streams, that makes feasibility — and therefore
//! frontier coverage — provably transfer from a skipped small-batch
//! point to its solved large-batch dominator (the NoC power term grows
//! with tokens/s, so with a finite budget a design feasible at batch 1
//! could in principle bust the budget at batch 4; see DESIGN.md §12).

use silicon_rl::config::{Granularity, RunConfig};
use silicon_rl::ir::Phase;
use silicon_rl::nn::backend::BackendSel;
use silicon_rl::rl::atlas::{self, AtlasResult};
use silicon_rl::rl::PointStatus;

fn contract_cfg() -> RunConfig {
    let mut cfg = RunConfig::default();
    cfg.backend = BackendSel::Native;
    cfg.artifacts_dir = "/nonexistent-artifacts".into();
    cfg.granularity = Granularity::Group;
    cfg.rl.episodes_per_node = 10;
    cfg.rl.warmup_steps = 10_000; // rollout-only: pure seeded action streams
    cfg.atlas.workloads = vec!["llama-3.2-1b".into()];
    cfg.atlas.phases = vec![Phase::Decode];
    cfg.atlas.seq_lens = vec![2048];
    cfg.atlas.batches = vec![1, 4];
    cfg.atlas.n_seeds = 1;
    cfg.atlas.warm = false;
    cfg.atlas.shrink = 0; // dominated points are skipped outright
    cfg.nodes_nm = vec![7];
    // power never binds (see module doc); area/memory still enforced
    for b in &mut cfg.mode.budgets {
        b.power_budget_mw = 1e9;
    }
    cfg
}

fn run_with_prune(prune: bool) -> AtlasResult {
    let mut cfg = contract_cfg();
    cfg.atlas.prune = prune;
    atlas::run(&cfg).unwrap()
}

/// The tentpole contract: pruning skips work, never changes answers.
#[test]
fn pruned_sweep_is_bit_identical_and_skips_are_covered() {
    let exact = run_with_prune(false);
    let pruned = run_with_prune(true);
    assert_eq!(exact.points.len(), pruned.points.len());

    // the exact sweep runs everything
    assert_eq!(exact.counters.pruned(), 0);
    for p in &exact.points {
        assert_eq!(p.status, PointStatus::Solved, "exact point {}", p.grid_index);
        assert!(
            !p.frontier.is_empty(),
            "exact point {} found no feasible design — the coverage \
             assertion below would be vacuous; raise episodes",
            p.grid_index
        );
    }

    // pruning must actually fire on this grid (batch 4 solves first and
    // dominates batch 1), or the contract is tested against nothing
    assert!(pruned.counters.pruned() > 0, "no points pruned");
    assert_eq!(
        pruned.counters.prune_fast + pruned.counters.prune_amortized,
        pruned.counters.pruned()
    );
    assert!(pruned.counters.episodes_run < pruned.counters.episodes_budget);

    for (e, p) in exact.points.iter().zip(&pruned.points) {
        assert_eq!(e.grid_index, p.grid_index);
        match p.status {
            // non-skipped points: bit-identical frontiers
            PointStatus::Solved | PointStatus::Shrunk { .. } => {
                let (fe, fp) = (e.frontier.frontier(), p.frontier.frontier());
                assert_eq!(fe.len(), fp.len(), "point {}: frontier size", p.grid_index);
                for (x, y) in fe.iter().zip(fp) {
                    let i = p.grid_index;
                    assert_eq!(x.perf_gops.to_bits(), y.perf_gops.to_bits(), "pt {i} perf");
                    assert_eq!(x.power_mw.to_bits(), y.power_mw.to_bits(), "pt {i} power");
                    assert_eq!(x.area_mm2.to_bits(), y.area_mm2.to_bits(), "pt {i} area");
                    assert_eq!(
                        x.tokens_per_s.to_bits(),
                        y.tokens_per_s.to_bits(),
                        "pt {i} tokens/s"
                    );
                    assert_eq!(x.episode, y.episode, "pt {i} episode tag");
                }
            }
            // skipped points: the justifying neighbor's achieved frontier
            // must cover every point the exact sweep found here
            PointStatus::Skipped { by, .. } => {
                assert!(p.frontier.is_empty());
                let justifier = &pruned.points[by];
                assert_eq!(justifier.grid_index, by);
                assert_eq!(justifier.status, PointStatus::Solved);
                for x in e.frontier.frontier() {
                    assert!(
                        justifier.frontier.frontier().iter().any(|q| q.covers_energy(x)),
                        "skipped point {} has exact frontier point \
                         (perf {}, {} mJ/tok, {} mm2) not covered by justifier {}",
                        p.grid_index,
                        x.perf_gops,
                        x.energy_mj_per_token(),
                        x.area_mm2,
                        by
                    );
                }
            }
        }
    }

    // the merged energy atlas loses nothing either: every exact merged
    // point is covered by the pruned sweep's merged atlas
    for (key, front) in &exact.atlas {
        let got = pruned.atlas.get(key).expect("atlas slab present");
        for x in front {
            assert!(
                got.iter().any(|q| q.covers_energy(x)),
                "merged atlas point lost under pruning"
            );
        }
    }
}

/// Warm mode: one shared cache spans the sweep, salted per scenario,
/// with occupancy surfaced on the result.
#[test]
fn warm_sweep_shares_cache_across_scenarios() {
    let mut cfg = contract_cfg();
    cfg.atlas.prune = false; // run both scenarios so two salts populate
    cfg.atlas.warm = true;
    let res = atlas::run(&cfg).unwrap();
    let occ = res.occupancy.expect("warm mode reports occupancy");
    assert!(occ.entries > 0, "shared cache never populated");
    // two scenario points (batch 1 and 4) → two distinct salts resident
    assert!(
        occ.salts.len() >= 2,
        "expected per-salt occupancy for both scenario points, got {}",
        occ.salts.len()
    );
    let per_salt_sum: u64 = occ.salts.iter().map(|&(_, n)| n).sum();
    assert_eq!(per_salt_sum, occ.entries as u64);
    for p in &res.points {
        assert_eq!(p.status, PointStatus::Solved);
        assert!(!p.frontier.is_empty(), "warm point {} empty", p.grid_index);
    }
}
