//! Determinism and correctness contract of the stateless evaluation
//! layer (DESIGN.md §5): serial and parallel drivers must produce
//! bit-identical results for a fixed seed, memo-cache hits must equal
//! recomputation, and `evaluate_many` must preserve input order. No AOT
//! artifacts needed — everything here runs the analytical pipeline.

use silicon_rl::config::{Granularity, RunConfig};
use silicon_rl::env::Action;
use silicon_rl::eval::{EvalCache, EvalOutcome, EvalScratch, Evaluator};
use silicon_rl::rl::{baselines, run_seeds_t};
use silicon_rl::util::Rng;

fn small_cfg(episodes: usize) -> RunConfig {
    let mut c = RunConfig::default();
    c.rl.episodes_per_node = episodes;
    c.granularity = Granularity::Group;
    c
}

fn random_action(rng: &mut Rng) -> Action {
    let mut a = Action::neutral();
    for v in a.cont.iter_mut() {
        *v = rng.uniform_in(-1.0, 1.0);
    }
    for d in a.deltas.iter_mut() {
        *d = rng.below(5) as i32 - 2;
    }
    a
}

fn assert_outcomes_identical(a: &EvalOutcome, b: &EvalOutcome, what: &str) {
    assert_eq!(a.reward.total.to_bits(), b.reward.total.to_bits(), "{what}: reward");
    assert_eq!(a.reward.score.to_bits(), b.reward.score.to_bits(), "{what}: score");
    assert_eq!(a.reward.feasible, b.reward.feasible, "{what}: feasible");
    assert_eq!(
        a.ppa.tokens_per_s.to_bits(),
        b.ppa.tokens_per_s.to_bits(),
        "{what}: tokens/s"
    );
    assert_eq!(
        a.ppa.power.total().to_bits(),
        b.ppa.power.total().to_bits(),
        "{what}: power"
    );
    assert_eq!(a.decoded.mesh, b.decoded.mesh, "{what}: mesh");
    assert_eq!(a.proj_steps, b.proj_steps, "{what}: projection steps");
    assert_eq!(a.tiles.len(), b.tiles.len(), "{what}: tile count");
    for (i, (x, y)) in a.full_state.iter().zip(&b.full_state).enumerate() {
        assert_eq!(x.to_bits(), y.to_bits(), "{what}: state dim {i}");
    }
}

#[test]
fn evaluate_many_serial_vs_parallel_bit_identical() {
    let cfg = small_cfg(1);
    for nm in [3u32, 28] {
        let ev = Evaluator::new(&cfg, nm);
        let mesh = ev.initial_mesh();
        let mut rng = Rng::new(42 + nm as u64);
        let actions: Vec<Action> = (0..13).map(|_| random_action(&mut rng)).collect();
        let serial = ev.evaluate_many(&mesh, &actions, 1);
        for threads in [2usize, 4, 16] {
            let par = ev.evaluate_many(&mesh, &actions, threads);
            assert_eq!(serial.len(), par.len());
            for (i, (s, p)) in serial.iter().zip(&par).enumerate() {
                assert_outcomes_identical(
                    s,
                    p,
                    &format!("{nm}nm, {threads} threads, action {i}"),
                );
            }
        }
    }
}

#[test]
fn evaluate_many_preserves_input_order() {
    // distinguishable actions: each candidate walks the mesh differently,
    // so any reordering of results is visible in the decoded mesh
    let cfg = small_cfg(1);
    let ev = Evaluator::new(&cfg, 7);
    let mesh = ev.initial_mesh();
    let actions: Vec<Action> = (0..5)
        .map(|i| {
            let mut a = Action::neutral();
            a.deltas = [i as i32 - 2, i as i32 - 2, 0, 0];
            a
        })
        .collect();
    let outs = ev.evaluate_many(&mesh, &actions, 4);
    let mut scratch = EvalScratch::default();
    for (i, (a, out)) in actions.iter().zip(&outs).enumerate() {
        let direct = ev.evaluate(&mesh, a, &mut scratch);
        assert_outcomes_identical(out, &direct, &format!("slot {i}"));
    }
}

#[test]
fn cached_outcome_equals_recomputed() {
    let cfg = small_cfg(1);
    let ev = Evaluator::new(&cfg, 3);
    let mesh = ev.initial_mesh();
    let mut rng = Rng::new(7);
    let mut cache = EvalCache::new(64);
    let mut scratch = EvalScratch::default();

    let actions: Vec<Action> = (0..6).map(|_| random_action(&mut rng)).collect();
    // first pass fills, second pass hits; every hit must equal a fresh
    // evaluation with a clean scratch
    for pass in 0..2 {
        for (i, a) in actions.iter().enumerate() {
            let through_cache = cache.evaluate(&ev, &mesh, a, &mut scratch);
            let fresh = ev.evaluate(&mesh, a, &mut EvalScratch::default());
            assert_outcomes_identical(
                &through_cache,
                &fresh,
                &format!("pass {pass}, action {i}"),
            );
        }
    }
    assert_eq!(cache.misses, actions.len() as u64);
    assert_eq!(cache.hits, actions.len() as u64);
}

#[test]
fn random_search_identical_across_worker_counts() {
    let cfg = small_cfg(32);
    let serial = baselines::random_search_t(&cfg, 7, &mut Rng::new(5), 1);
    for threads in [2usize, 8] {
        let par = baselines::random_search_t(&cfg, 7, &mut Rng::new(5), threads);
        assert_eq!(serial.feasible_count, par.feasible_count, "{threads} threads");
        assert_eq!(serial.pareto.len(), par.pareto.len(), "{threads} threads");
        assert_eq!(serial.episodes.len(), par.episodes.len());
        for (e1, e2) in serial.episodes.iter().zip(&par.episodes) {
            assert_eq!(e1.reward.to_bits(), e2.reward.to_bits());
            assert_eq!(e1.score.to_bits(), e2.score.to_bits());
            assert_eq!(e1.best_score.to_bits(), e2.best_score.to_bits());
            assert_eq!((e1.mesh_w, e1.mesh_h), (e2.mesh_w, e2.mesh_h));
            assert_eq!(e1.unique_configs, e2.unique_configs);
        }
        match (&serial.best, &par.best) {
            (Some(a), Some(b)) => {
                assert_eq!(a.episode, b.episode);
                assert_outcomes_identical(&a.outcome, &b.outcome, "best outcome");
            }
            (None, None) => {}
            _ => panic!("best presence diverged between worker counts"),
        }
    }
}

#[test]
fn grid_search_identical_across_worker_counts() {
    let cfg = small_cfg(30);
    let serial = baselines::grid_search_t(&cfg, 14, &mut Rng::new(9), 1);
    let par = baselines::grid_search_t(&cfg, 14, &mut Rng::new(9), 4);
    for (e1, e2) in serial.episodes.iter().zip(&par.episodes) {
        assert_eq!(e1.reward.to_bits(), e2.reward.to_bits());
        assert_eq!((e1.mesh_w, e1.mesh_h), (e2.mesh_w, e2.mesh_h));
    }
}

#[test]
fn multi_seed_identical_across_worker_counts() {
    let cfg = small_cfg(18);
    let search = |c: &RunConfig, nm: u32, rng: &mut Rng| {
        baselines::random_search_t(c, nm, rng, 1)
    };
    let serial = run_seeds_t(&cfg, 3, 5, 1, search);
    for threads in [2usize, 5, 8] {
        let par = run_seeds_t(&cfg, 3, 5, threads, search);
        assert_eq!(serial.seeds, par.seeds, "{threads} threads");
        assert_eq!(serial.failures, par.failures);
        for (a, b) in [
            (serial.tokens_per_s, par.tokens_per_s),
            (serial.power_mw, par.power_mw),
            (serial.area_mm2, par.area_mm2),
            (serial.score, par.score),
            (serial.feasible_frac, par.feasible_frac),
        ] {
            assert_eq!(a.mean.to_bits(), b.mean.to_bits(), "{threads} threads: mean");
            assert_eq!(a.std.to_bits(), b.std.to_bits(), "{threads} threads: std");
        }
        assert_eq!(serial.pareto.len(), par.pareto.len());
    }
}

#[test]
fn candidate_batch_shapes_search_not_thread_count() {
    // the knob that changes trajectories is candidate_batch; threads never
    // does. Two different batch sizes may legitimately differ...
    let mut cfg_a = small_cfg(24);
    cfg_a.rl.candidate_batch = 1;
    let mut cfg_b = small_cfg(24);
    cfg_b.rl.candidate_batch = 8;
    let a = baselines::random_search_t(&cfg_a, 3, &mut Rng::new(3), 2);
    let b = baselines::random_search_t(&cfg_b, 3, &mut Rng::new(3), 2);
    // ...but both still consume the full episode budget and stay finite
    assert_eq!(a.episodes.len(), 24);
    assert_eq!(b.episodes.len(), 24);
    assert!(a.episodes.iter().all(|e| e.reward.is_finite()));
    assert!(b.episodes.iter().all(|e| e.reward.is_finite()));
    // batch=1 reproduces itself regardless of the worker count
    let a2 = baselines::random_search_t(&cfg_a, 3, &mut Rng::new(3), 8);
    for (x, y) in a.episodes.iter().zip(&a2.episodes) {
        assert_eq!(x.reward.to_bits(), y.reward.to_bits());
    }
}
