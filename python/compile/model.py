# L2 — JAX definitions of every neural network in the paper's RL stack.
#
# Sections of the paper implemented here:
#   §3.4   SAC actor: 52 → [256,256] GELU trunk → 20 discrete logits +
#          tanh-squashed Gaussian continuous head (30 means + 30 log-stds,
#          log-std clamped to [-20, 2]).
#   §3.15  Mixture-of-Experts gating on the continuous head (Eq 54) with a
#          load-balance penalty (Eq 55); surrogate PPA head (Eq 61/65).
#   §3.11  SAC update: twin critics [82→256→256→1], clipped double-Q
#          targets (Eq 46/47), entropy auto-tuning (Eq 45/60, log α ∈
#          [-10,10]), Polyak target update (τ=0.005), PER importance
#          weights and |TD| priorities out.
#   §3.16  World model f_ω: [82] → [128,64] → Δs residual (Eq 69) + MSE
#          update at half the critic LR.
#
# Every dense layer routes through the L1 Pallas kernel
# (kernels.fused_mlp.linear), forward and backward, so the whole update
# lowers into kernel instances inside one HLO module.
#
# Deviation (documented in DESIGN.md §4): the paper samples the 4 discrete
# mesh/SC deltas "separately" and never states their training signal; we
# train the discrete head with a REINFORCE term on batch-mean-baselined
# immediate reward inside the same actor update. The critic input stays
# 82 = 52 + 30 (continuous action only), exactly as §3.11 specifies.
#
# All sampling noise (ε for reparameterization) is an *input*: RNG lives in
# the Rust coordinator so runs are seed-controlled from one place.
import jax
import jax.numpy as jnp

from .kernels.fused_mlp import linear

# ---------------------------------------------------------------------------
# Hyperparameters (Table 6). Baked into the lowered HLO; recorded in the
# artifact manifest so the Rust side can assert it was built from the same
# config it is running.
HYPER = dict(
    state_dim=52,          # SAC-optimized state subset (Table 2)
    full_state_dim=73,     # full state (encoded in Rust; subset taken there)
    act_dim=30,            # continuous action dims (Table 3)
    disc_dim=20,           # 4 mesh/SC deltas x 5-way one-hot
    hidden=256,            # actor/critic hidden width
    n_experts=4,           # MoE experts on the continuous head (Eq 54)
    lr=3e-4,               # actor / critic / alpha learning rate
    gamma=0.99,
    tau=0.005,
    target_entropy=-30.0,  # -d_a
    logstd_min=-20.0,
    logstd_max=2.0,
    log_alpha_min=-10.0,
    log_alpha_max=10.0,
    lambda_lb=0.01,        # MoE load-balance weight (Eq 55)
    wm_hidden=(128, 64),   # world model hidden dims (§3.16)
    wm_lr=1.5e-4,          # half the critic LR
    sur_hidden=(128, 64),  # surrogate PPA model hidden dims
    sur_lr=3e-4,
    batch=256,             # SAC minibatch (Table 6)
    mpc_batch=64,          # MPC candidate count K (Table 6)
    adam_b1=0.9,
    adam_b2=0.999,
    adam_eps=1e-8,
)


# ---------------------------------------------------------------------------
# Parameter shapes. The Rust side initializes parameters (He for GELU
# trunks, Xavier for linear heads) from these manifest-recorded shapes.
def actor_shapes(h=HYPER):
    s, hid, k = h["state_dim"], h["hidden"], h["n_experts"]
    a, d = h["act_dim"], h["disc_dim"]
    return {
        "W1": (s, hid), "b1": (hid,),         # trunk layer 1 (Eq 1)
        "W5": (hid, hid), "b5": (hid,),       # trunk layer 2 (Eq 2)
        "W2": (hid, d), "b2": (d,),           # discrete head (Eq 3)
        "Wg": (s, k), "bg": (k,),             # MoE gate u_k^T s (Eq 54)
        "W3": (hid, k * a), "b3": (k * a,),   # per-expert mean heads (Eq 4)
        "W4": (hid, k * a), "b4": (k * a,),   # per-expert log-std heads (Eq 5)
    }


def critic_shapes(h=HYPER):
    s, a, hid = h["state_dim"], h["act_dim"], h["hidden"]
    return {
        "Wa": (s + a, hid), "ba": (hid,),
        "Wb": (hid, hid), "bb": (hid,),
        "Wc": (hid, 1), "bc": (1,),
    }


def _mlp3_shapes(in_dim, hidden, out_dim):
    h1, h2 = hidden
    return {
        "W1": (in_dim, h1), "b1": (h1,),
        "W2": (h1, h2), "b2": (h2,),
        "W3": (h2, out_dim), "b3": (out_dim,),
    }


def wm_shapes(h=HYPER):
    return _mlp3_shapes(h["state_dim"] + h["act_dim"], h["wm_hidden"], h["state_dim"])


def sur_shapes(h=HYPER):
    return _mlp3_shapes(h["state_dim"] + h["act_dim"], h["sur_hidden"], 3)


# ---------------------------------------------------------------------------
# Forward passes
def actor_forward(p, s):
    """Actor network (§3.4 + MoE head §3.15).

    Returns (mu, log_std, disc_logits, gates):
      mu, log_std : [B, 30] mixture continuous head (pre-squash)
      disc_logits : [B, 20] (4 deltas x 5 options)
      gates       : [B, K] MoE routing weights
    """
    h = HYPER
    b = s.shape[0]
    k, a = h["n_experts"], h["act_dim"]
    h1 = linear(s, p["W1"], p["b1"], "gelu")
    h2 = linear(h1, p["W5"], p["b5"], "gelu")
    disc_logits = linear(h2, p["W2"], p["b2"])
    gates = jax.nn.softmax(linear(s, p["Wg"], p["bg"]), axis=-1)
    mu_e = jnp.tanh(linear(h2, p["W3"], p["b3"]).reshape(b, k, a))
    ls_e = linear(h2, p["W4"], p["b4"]).reshape(b, k, a)
    mu = jnp.einsum("bk,bka->ba", gates, mu_e)
    log_std = jnp.einsum("bk,bka->ba", gates, ls_e)
    log_std = jnp.clip(log_std, h["logstd_min"], h["logstd_max"])
    return mu, log_std, disc_logits, gates


def sample_squashed(mu, log_std, eps):
    """a = tanh(mu + sigma*eps) with the change-of-variables log-prob."""
    std = jnp.exp(log_std)
    u = mu + std * eps
    a = jnp.tanh(u)
    # log N(u; mu, sigma) - sum log(1 - tanh(u)^2)
    logp = -0.5 * (((u - mu) / std) ** 2 + 2.0 * log_std + jnp.log(2.0 * jnp.pi))
    logp = logp - jnp.log(jnp.clip(1.0 - a ** 2, 1e-6, None))
    return a, jnp.sum(logp, axis=-1)


def critic_forward(p, s, a):
    """Q(s, a) — twin-critic body [82 → 256 → 256 → 1] (§3.11)."""
    x = jnp.concatenate([s, a], axis=-1)
    h1 = linear(x, p["Wa"], p["ba"], "gelu")
    h2 = linear(h1, p["Wb"], p["bb"], "gelu")
    return linear(h2, p["Wc"], p["bc"])[:, 0]


def _mlp3_forward(p, x):
    h1 = linear(x, p["W1"], p["b1"], "gelu")
    h2 = linear(h1, p["W2"], p["b2"], "gelu")
    return linear(h2, p["W3"], p["b3"])


def wm_forward(p, s, a):
    """World model: residual next-state prediction (Eq 69)."""
    return s + _mlp3_forward(p, jnp.concatenate([s, a], axis=-1))


def sur_forward(p, s, a):
    """Surrogate PPA heads: [power, perf, area] predictions (Eq 61)."""
    return _mlp3_forward(p, jnp.concatenate([s, a], axis=-1))


# ---------------------------------------------------------------------------
# Adam (bias-corrected), over pytrees. The step counter is an f32 input.
def adam_step(params, grads, m, v, t, lr, h=HYPER):
    b1, b2, eps = h["adam_b1"], h["adam_b2"], h["adam_eps"]
    t = t + 1.0
    new_m = jax.tree_util.tree_map(lambda mm, g: b1 * mm + (1 - b1) * g, m, grads)
    new_v = jax.tree_util.tree_map(lambda vv, g: b2 * vv + (1 - b2) * g * g, v, grads)
    corr1 = 1.0 - b1 ** t
    corr2 = 1.0 - b2 ** t

    def upd(pp, mm, vv):
        return pp - lr * (mm / corr1) / (jnp.sqrt(vv / corr2) + eps)

    new_p = jax.tree_util.tree_map(upd, params, new_m, new_v)
    return new_p, new_m, new_v


# ---------------------------------------------------------------------------
# SAC update step (§3.11, Algorithm 1 line 12). One fused HLO module.
def sac_update(all_in):
    """Inputs: {"state": trainable state, "batch": PER minibatch}.

    state:
      actor, actor_m, actor_v          — actor params + Adam moments
      c1, c1_m, c1_v, c2, c2_m, c2_v   — twin critics + Adam moments
      t1, t2                           — Polyak target critics
      log_alpha, la_m, la_v            — entropy temperature + moments
      step                             — Adam step counter (f32 scalar)
    batch:
      s [B,52], a [B,30], ad [B,20] (one-hot discrete), r [B], s2 [B,52],
      done [B], w [B] (PER importance weights),
      eps_cur [B,30], eps_next [B,30] (reparameterization noise)
    Outputs mirror `state` (updated) plus metrics (td_abs for PER
    priorities, losses, alpha, entropy estimate).
    """
    h = HYPER
    st, b = all_in["state"], all_in["batch"]
    s, a, ad, r = b["s"], b["a"], b["ad"], b["r"]
    s2, done, w = b["s2"], b["done"], b["w"]
    gamma, tau, lr = h["gamma"], h["tau"], h["lr"]
    log_alpha = jnp.clip(st["log_alpha"], h["log_alpha_min"], h["log_alpha_max"])
    alpha = jnp.exp(log_alpha)

    # ---- critic target (Eq 46): clipped double-Q with entropy bonus
    mu2, ls2, _, _ = actor_forward(st["actor"], s2)
    a2, logp2 = sample_squashed(mu2, ls2, b["eps_next"])
    qt1 = critic_forward(st["t1"], s2, a2)
    qt2 = critic_forward(st["t2"], s2, a2)
    y = r + gamma * (1.0 - done) * (jnp.minimum(qt1, qt2) - alpha * logp2)
    y = jax.lax.stop_gradient(y)

    # ---- critic update (Eq 47), PER-weighted
    def critic_loss(cp):
        q = critic_forward(cp, s, a)
        return jnp.mean(w * (q - y) ** 2), q

    (c1_loss, q1), g1 = jax.value_and_grad(critic_loss, has_aux=True)(st["c1"])
    (c2_loss, _), g2 = jax.value_and_grad(critic_loss, has_aux=True)(st["c2"])
    c1_new, c1_m, c1_v = adam_step(st["c1"], g1, st["c1_m"], st["c1_v"], st["step"], lr)
    c2_new, c2_m, c2_v = adam_step(st["c2"], g2, st["c2_m"], st["c2_v"], st["step"], lr)
    td_abs = jnp.abs(q1 - y)  # PER priority source (§3.11)

    # ---- actor update (Eq 58) + discrete REINFORCE + MoE balance (Eq 55)
    adv_disc = jax.lax.stop_gradient(r - jnp.mean(r))

    def actor_loss(ap):
        mu, ls, dl, gates = actor_forward(ap, s)
        a_new, logp = sample_squashed(mu, ls, b["eps_cur"])
        q = jnp.minimum(
            critic_forward(c1_new, s, a_new), critic_forward(c2_new, s, a_new)
        )
        l_cont = jnp.mean(w * (alpha * logp - q))
        logp_d = jnp.sum(jax.nn.log_softmax(dl.reshape(-1, 4, 5), axis=-1)
                         * ad.reshape(-1, 4, 5), axis=(1, 2))
        l_disc = -jnp.mean(w * adv_disc * logp_d)
        gbar = jnp.mean(gates, axis=0)
        l_moe = h["lambda_lb"] * h["n_experts"] * jnp.sum(gbar ** 2)
        return l_cont + l_disc + l_moe, logp

    (a_loss, logp_cur), ga = jax.value_and_grad(actor_loss, has_aux=True)(st["actor"])
    actor_new, actor_m, actor_v = adam_step(
        st["actor"], ga, st["actor_m"], st["actor_v"], st["step"], lr
    )

    # ---- entropy temperature (Eq 45/60), gradient clipped to [-1, 1]
    logp_sg = jax.lax.stop_gradient(logp_cur)
    grad_la = -jnp.mean(logp_sg + h["target_entropy"])  # dL/d(log_alpha)
    grad_la = jnp.clip(grad_la, -1.0, 1.0)
    la_new, la_m, la_v = adam_step(
        st["log_alpha"], grad_la, st["la_m"], st["la_v"], st["step"], lr
    )
    la_new = jnp.clip(la_new, h["log_alpha_min"], h["log_alpha_max"])
    alpha_loss = -la_new * jnp.mean(logp_sg + h["target_entropy"])

    # ---- Polyak target update (tau = 0.005)
    polyak = lambda tp, op: jax.tree_util.tree_map(
        lambda t_, o_: (1.0 - tau) * t_ + tau * o_, tp, op
    )

    return {
        "state": {
            "actor": actor_new, "actor_m": actor_m, "actor_v": actor_v,
            "c1": c1_new, "c1_m": c1_m, "c1_v": c1_v,
            "c2": c2_new, "c2_m": c2_m, "c2_v": c2_v,
            "t1": polyak(st["t1"], c1_new), "t2": polyak(st["t2"], c2_new),
            "log_alpha": la_new, "la_m": la_m, "la_v": la_v,
            "step": st["step"] + 1.0,
        },
        "metrics": {
            "td_abs": td_abs,
            "critic_loss": 0.5 * (c1_loss + c2_loss),
            "actor_loss": a_loss,
            "alpha_loss": alpha_loss,
            "alpha": jnp.exp(la_new),
            "entropy": -jnp.mean(logp_cur),
        },
    }


# ---------------------------------------------------------------------------
# World-model update (§3.16): MSE on state deltas, half the critic LR.
def wm_update(all_in):
    h = HYPER
    st, b = all_in["state"], all_in["batch"]
    target_delta = b["s2"] - b["s"]

    def loss(p):
        pred = _mlp3_forward(p, jnp.concatenate([b["s"], b["a"]], axis=-1))
        return jnp.mean(jnp.sum((pred - target_delta) ** 2, axis=-1))

    l, g = jax.value_and_grad(loss)(st["wm"])
    wm_new, m, v = adam_step(st["wm"], g, st["wm_m"], st["wm_v"], st["step"], h["wm_lr"])
    return {
        "state": {"wm": wm_new, "wm_m": m, "wm_v": v, "step": st["step"] + 1.0},
        "metrics": {"loss": l},
    }


# ---------------------------------------------------------------------------
# Surrogate update (Eq 65): weighted MSE over [power, perf, area] heads.
def sur_update(all_in):
    h = HYPER
    st, b = all_in["state"], all_in["batch"]
    wq = jnp.array([1.0, 1.0, 1.0], jnp.float32)  # w_q of Eq 65

    def loss(p):
        pred = _mlp3_forward(p, jnp.concatenate([b["s"], b["a"]], axis=-1))
        return jnp.mean(jnp.sum(wq * (pred - b["ppa"]) ** 2, axis=-1))

    l, g = jax.value_and_grad(loss)(st["sur"])
    sur_new, m, v = adam_step(
        st["sur"], g, st["sur_m"], st["sur_v"], st["step"], h["sur_lr"]
    )
    return {
        "state": {"sur": sur_new, "sur_m": m, "sur_v": v, "step": st["step"] + 1.0},
        "metrics": {"loss": l},
    }


# ---------------------------------------------------------------------------
# Pure-forward entry points (lowered at several batch sizes by aot.py)
def actor_fwd(all_in):
    mu, ls, dl, gates = actor_forward(all_in["actor"], all_in["s"])
    return {"mu": mu, "log_std": ls, "disc_logits": dl, "gates": gates}


def wm_fwd(all_in):
    return {"s_next": wm_forward(all_in["wm"], all_in["s"], all_in["a"])}


def sur_fwd(all_in):
    return {"ppa": sur_forward(all_in["sur"], all_in["s"], all_in["a"])}
