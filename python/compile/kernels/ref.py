# Pure-jnp correctness oracle for the Pallas fused-linear kernel.
#
# Every network in model.py routes its dense layers through
# kernels.fused_mlp.linear; this module is the independent reference the
# pytest suite compares against (allclose over a hypothesis shape sweep).
import jax.numpy as jnp

# tanh-approximate GELU constant: sqrt(2/pi)
_GELU_C = 0.7978845608028654


def gelu_ref(x):
    """tanh-approximate GELU, matching the kernel's epilogue exactly."""
    x32 = x.astype(jnp.float32)
    y = 0.5 * x32 * (1.0 + jnp.tanh(_GELU_C * (x32 + 0.044715 * x32 ** 3)))
    return y.astype(x.dtype)


def gelu_grad_ref(x):
    """d/dx of tanh-approximate GELU (used by the custom VJP)."""
    x32 = x.astype(jnp.float32)
    t = jnp.tanh(_GELU_C * (x32 + 0.044715 * x32 ** 3))
    dt = (1.0 - t ** 2) * _GELU_C * (1.0 + 3 * 0.044715 * x32 ** 2)
    return (0.5 * (1.0 + t) + 0.5 * x32 * dt).astype(x.dtype)


def linear_ref(x, w, b, act="none"):
    """Reference y = act(x @ w + b) with f32 accumulation."""
    y = jnp.dot(x.astype(jnp.float32), w.astype(jnp.float32))
    y = y + b.astype(jnp.float32)
    if act == "gelu":
        y = 0.5 * y * (1.0 + jnp.tanh(_GELU_C * (y + 0.044715 * y ** 3)))
    elif act != "none":
        raise ValueError(f"unknown activation {act!r}")
    return y.astype(x.dtype)
