# L1 — Pallas fused linear(+GELU) kernel.
#
# This is the compute hot-spot of the paper's RL stack: every dense layer of
# the SAC actor, twin critics, world model and PPA surrogate goes through
# `linear()` below, so the B=256 `sac_update` step is ~30 instances of this
# kernel (forward *and* backward, via the custom VJP).
#
# TPU mapping (DESIGN.md §Hardware-Adaptation): the grid tiles the output
# into (bm × bn) blocks; each grid cell keeps an (bm × K) activation panel
# and a (K × bn) weight panel resident in VMEM and accumulates in f32 on the
# MXU, fusing the bias add and tanh-GELU epilogue so the pre-activation
# never round-trips to HBM. Block dims are multiples of 8 (sublane) and the
# lane dim targets 128. interpret=True is mandatory here — the CPU PJRT
# plugin cannot execute Mosaic custom-calls — so VMEM/MXU behaviour is
# estimated, not measured (EXPERIMENTS.md §Perf).
import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import gelu_grad_ref

_GELU_C = 0.7978845608028654

# VMEM budget (bytes) a single grid cell may use for x-panel + w-panel +
# accumulator. Real TPU VMEM is ~16 MiB; stay well under half to leave room
# for double-buffering the next panels.
_VMEM_BUDGET = 6 * 1024 * 1024


def _round_up(v, m):
    return ((v + m - 1) // m) * m


def _pick_blocks(m, n, k):
    """Choose (bm, bn) output-tile dims under the VMEM budget.

    bm is a multiple of 8 (sublanes), bn a multiple of 128 (lanes) when the
    problem is large enough; tiny dims are padded up instead of tiled.

    Perf iteration (EXPERIMENTS.md §Perf L1): caps raised 128 -> 256.
    The networks' largest instances (256x256x256) fit a single grid cell
    well inside the VMEM budget; fewer grid cells cut per-cell dispatch
    overhead in the interpret-lowered HLO and map to fewer, fuller MXU
    passes on real TPU.
    """
    bm = min(256, _round_up(m, 8))
    bn = min(256, _round_up(n, 128))
    # shrink bm if the x panel + w panel + acc would blow the budget
    while bm > 8 and 4 * (bm * k + k * bn + bm * bn) > _VMEM_BUDGET:
        bm //= 2
    return bm, bn


def _kernel(x_ref, w_ref, b_ref, o_ref, *, act):
    """One (bm × bn) output tile: f32 MXU accumulate + fused epilogue."""
    acc = jnp.dot(
        x_ref[...], w_ref[...], preferred_element_type=jnp.float32
    )
    acc = acc + b_ref[...].astype(jnp.float32)
    if act == "gelu":
        acc = 0.5 * acc * (1.0 + jnp.tanh(_GELU_C * (acc + 0.044715 * acc ** 3)))
    o_ref[...] = acc.astype(o_ref.dtype)


def _matmul_bias(x, w, b, act):
    """Pallas-tiled y = act(x @ w + b); pads ragged dims, crops the result."""
    m, k = x.shape
    k2, n = w.shape
    assert k == k2, f"inner dims mismatch: {k} vs {k2}"
    assert b.shape == (n,), f"bias shape {b.shape} != ({n},)"
    bm, bn = _pick_blocks(m, n, k)
    mp, np_ = _round_up(m, bm), _round_up(n, bn)
    xp = jnp.pad(x, ((0, mp - m), (0, 0))) if mp != m else x
    wp = jnp.pad(w, ((0, 0), (0, np_ - n))) if np_ != n else w
    bp = (jnp.pad(b, (0, np_ - n)) if np_ != n else b).reshape(1, np_)
    grid = (mp // bm, np_ // bn)
    out = pl.pallas_call(
        functools.partial(_kernel, act=act),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, k), lambda i, j: (i, 0)),
            pl.BlockSpec((k, bn), lambda i, j: (0, j)),
            pl.BlockSpec((1, bn), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), x.dtype),
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls
    )(xp, wp, bp)
    return out[:m, :n]


@functools.partial(jax.custom_vjp, nondiff_argnums=(3,))
def linear(x, w, b, act="none"):
    """act(x @ w + b) through the Pallas kernel, differentiable.

    The custom VJP keeps the backward matmuls (dx = g·wᵀ, dw = xᵀ·g) on the
    same kernel, so the whole SAC update — forward and backward — runs
    through L1.
    """
    return _matmul_bias(x, w, b, act)


def _linear_fwd(x, w, b, act):
    pre = _matmul_bias(x, w, b, "none")
    if act == "gelu":
        out = 0.5 * pre * (1.0 + jnp.tanh(_GELU_C * (pre + 0.044715 * pre ** 3)))
    else:
        out = pre
    return out, (x, w, pre)


def _zeros_bias(n, dtype):
    return jnp.zeros((n,), dtype)


def _linear_bwd(act, res, g):
    x, w, pre = res
    if act == "gelu":
        g = g * gelu_grad_ref(pre)
    dx = _matmul_bias(g, w.T, _zeros_bias(w.shape[0], g.dtype), "none")
    dw = _matmul_bias(x.T, g, _zeros_bias(g.shape[1], g.dtype), "none")
    db = jnp.sum(g, axis=0)
    return dx, dw, db


linear.defvjp(_linear_fwd, _linear_bwd)
