# L1: Pallas kernels for the paper's compute hot-spot (fused linear+GELU).
from .fused_mlp import linear  # noqa: F401
from .ref import gelu_ref, linear_ref  # noqa: F401
