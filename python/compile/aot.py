# AOT lowering: every L2 entry point -> artifacts/*.hlo.txt + manifest.json.
#
# Interchange format is HLO *text*, not a serialized HloModuleProto: jax
# >= 0.5 emits protos with 64-bit instruction ids which xla_extension 0.5.1
# (the version the published `xla` 0.1.6 rust crate links) rejects
# (`proto.id() <= INT_MAX`). The text parser reassigns ids and round-trips
# cleanly. Lowered with return_tuple=True; the rust side unwraps the tuple.
#
# The manifest makes the rust runtime fully table-driven:
#   entrypoints.<name>.inputs/outputs — flattened (name, shape, dtype) in
#     the exact positional order of the lowered computation;
#   stores — every named persistent array (params, Adam moments, targets,
#     log_alpha, step counters) with shape + init recipe, so parameter
#     initialization happens in rust under rust-owned seeds;
#   hyper — the Table-6 hyperparameters baked into the HLO.
#
# Naming convention consumed by rust/src/runtime:
#   input "state/<k>"  -> parameter store (prefix stripped)
#   input "batch/<k>"  -> per-call tensor
#   anything else      -> per-call tensor (pure-forward entry points also
#     list bare store names like "actor/W1", looked up directly)
import argparse
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import model as M

F32 = jnp.float32


def _zeros(shapes):
    return {k: jnp.zeros(v, F32) for k, v in shapes.items()}


def _path_name(path):
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return "/".join(parts)


def _flat_specs(tree):
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        assert leaf.dtype == jnp.float32, f"{_path_name(path)}: {leaf.dtype}"
        out.append(
            {"name": _path_name(path), "shape": [int(d) for d in leaf.shape],
             "dtype": "f32"}
        )
    return out


def to_hlo_text(lowered):
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


# ---------------------------------------------------------------------------
# Example (zero) pytrees describing each entry point's signature
def sac_state_example():
    actor = _zeros(M.actor_shapes())
    c1, c2 = _zeros(M.critic_shapes()), _zeros(M.critic_shapes())
    z = lambda tree: jax.tree_util.tree_map(jnp.zeros_like, tree)
    scalar = jnp.zeros((), F32)
    return {
        "actor": actor, "actor_m": z(actor), "actor_v": z(actor),
        "c1": c1, "c1_m": z(c1), "c1_v": z(c1),
        "c2": c2, "c2_m": z(c2), "c2_v": z(c2),
        "t1": z(c1), "t2": z(c2),
        "log_alpha": scalar, "la_m": scalar, "la_v": scalar,
        "step": scalar,
    }


def sac_batch_example(B):
    h = M.HYPER
    return {
        "s": jnp.zeros((B, h["state_dim"]), F32),
        "a": jnp.zeros((B, h["act_dim"]), F32),
        "ad": jnp.zeros((B, h["disc_dim"]), F32),
        "r": jnp.zeros((B,), F32),
        "s2": jnp.zeros((B, h["state_dim"]), F32),
        "done": jnp.zeros((B,), F32),
        "w": jnp.zeros((B,), F32),
        "eps_cur": jnp.zeros((B, h["act_dim"]), F32),
        "eps_next": jnp.zeros((B, h["act_dim"]), F32),
    }


def wm_state_example():
    wm = _zeros(M.wm_shapes())
    z = jax.tree_util.tree_map(jnp.zeros_like, wm)
    return {"wm": wm, "wm_m": z, "wm_v": jax.tree_util.tree_map(jnp.zeros_like, wm),
            "step": jnp.zeros((), F32)}


def sur_state_example():
    sur = _zeros(M.sur_shapes())
    z = lambda: jax.tree_util.tree_map(jnp.zeros_like, sur)
    return {"sur": sur, "sur_m": z(), "sur_v": z(), "step": jnp.zeros((), F32)}


def entrypoints():
    h = M.HYPER
    B, K = h["batch"], h["mpc_batch"]
    sd, ad = h["state_dim"], h["act_dim"]
    eps = []

    def fwd_batch(b, with_a, extra=None):
        d = {"s": jnp.zeros((b, sd), F32)}
        if with_a:
            d["a"] = jnp.zeros((b, ad), F32)
        if extra:
            d.update(extra)
        return d

    actor = _zeros(M.actor_shapes())
    wm = {"wm": _zeros(M.wm_shapes())}
    sur = {"sur": _zeros(M.sur_shapes())}
    for b in (1, K, B):
        eps.append((f"actor_fwd_b{b}", M.actor_fwd,
                    {"actor": actor, **fwd_batch(b, False)}))
    for b in (K, B):
        eps.append((f"wm_fwd_b{b}", M.wm_fwd, {**wm, **fwd_batch(b, True)}))
    eps.append((f"sur_fwd_b{K}", M.sur_fwd, {**sur, **fwd_batch(K, True)}))
    eps.append(("sac_update", M.sac_update,
                {"state": sac_state_example(), "batch": sac_batch_example(B)}))
    eps.append(("wm_update", M.wm_update,
                {"state": wm_state_example(),
                 "batch": {"s": jnp.zeros((B, sd), F32),
                           "a": jnp.zeros((B, ad), F32),
                           "s2": jnp.zeros((B, sd), F32)}}))
    eps.append(("sur_update", M.sur_update,
                {"state": sur_state_example(),
                 "batch": {"s": jnp.zeros((B, sd), F32),
                           "a": jnp.zeros((B, ad), F32),
                           "ppa": jnp.zeros((B, 3), F32)}}))
    return eps


# ---------------------------------------------------------------------------
# Store init recipes (consumed by rust/src/nn/store.rs)
def store_inits():
    """name -> {shape, init} for every persistent array."""
    inits = {}

    def add_net(prefix, shapes, with_adam=True):
        for k, shp in shapes.items():
            init = "he" if k.startswith("W") else "zeros"
            inits[f"{prefix}/{k}"] = {"shape": list(shp), "init": init}
            if with_adam:
                inits[f"{prefix}_m/{k}"] = {"shape": list(shp), "init": "zeros"}
                inits[f"{prefix}_v/{k}"] = {"shape": list(shp), "init": "zeros"}

    add_net("actor", M.actor_shapes())
    add_net("c1", M.critic_shapes())
    add_net("c2", M.critic_shapes())
    for tgt, src in (("t1", "c1"), ("t2", "c2")):
        for k, shp in M.critic_shapes().items():
            inits[f"{tgt}/{k}"] = {"shape": list(shp), "init": f"copy:{src}/{k}"}
    # log alpha starts at ln(0.2): initial entropy coefficient 0.2 (Table 6)
    inits["log_alpha"] = {"shape": [], "init": "const:-1.6094379"}
    inits["la_m"] = {"shape": [], "init": "zeros"}
    inits["la_v"] = {"shape": [], "init": "zeros"}
    inits["step"] = {"shape": [], "init": "zeros"}
    add_net("wm", M.wm_shapes())
    add_net("sur", M.sur_shapes())
    return inits


def main():
    ap = argparse.ArgumentParser(description="AOT-lower L2 models to HLO text")
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--only", default=None, help="lower a single entrypoint")
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    manifest = {"hyper": M.HYPER, "stores": store_inits(), "entrypoints": {}}
    for name, fn, example in entrypoints():
        if args.only and name != args.only:
            continue
        out_shapes = jax.eval_shape(fn, example)
        lowered = jax.jit(fn).lower(example)
        text = to_hlo_text(lowered)
        fname = f"{name}.hlo.txt"
        with open(os.path.join(args.out_dir, fname), "w") as f:
            f.write(text)
        manifest["entrypoints"][name] = {
            "file": fname,
            "inputs": _flat_specs(example),
            "outputs": _flat_specs(out_shapes),
        }
        print(f"lowered {name}: {len(text)} chars, "
              f"{len(manifest['entrypoints'][name]['inputs'])} inputs, "
              f"{len(manifest['entrypoints'][name]['outputs'])} outputs")

    with open(os.path.join(args.out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1, sort_keys=True)
    print(f"wrote manifest with {len(manifest['entrypoints'])} entrypoints")


if __name__ == "__main__":
    main()
