# AOT manifest contract: the rust runtime is table-driven off
# artifacts/manifest.json; these tests pin the contract.
import json
import os

import pytest

from compile import aot
from compile import model as M

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


def _manifest():
    path = os.path.join(ART, "manifest.json")
    if not os.path.exists(path):
        pytest.skip("artifacts not built (run `make artifacts`)")
    with open(path) as f:
        return json.load(f)


def test_entrypoint_inventory():
    m = _manifest()
    expected = {
        "actor_fwd_b1", "actor_fwd_b64", "actor_fwd_b256",
        "wm_fwd_b64", "wm_fwd_b256", "sur_fwd_b64",
        "sac_update", "wm_update", "sur_update",
    }
    assert set(m["entrypoints"]) == expected
    for name, ep in m["entrypoints"].items():
        assert os.path.exists(os.path.join(ART, ep["file"])), name
        assert ep["inputs"] and ep["outputs"], name


def test_sac_update_io_names_round_trip():
    m = _manifest()
    ep = m["entrypoints"]["sac_update"]
    in_state = {i["name"] for i in ep["inputs"] if i["name"].startswith("state/")}
    out_state = {o["name"] for o in ep["outputs"] if o["name"].startswith("state/")}
    # every persistent input is produced as an output (store write-back)
    assert in_state == out_state
    batch = {i["name"] for i in ep["inputs"] if i["name"].startswith("batch/")}
    assert batch == {
        "batch/s", "batch/a", "batch/ad", "batch/r", "batch/s2", "batch/done",
        "batch/w", "batch/eps_cur", "batch/eps_next",
    }
    metrics = {o["name"] for o in ep["outputs"] if o["name"].startswith("metrics/")}
    assert "metrics/td_abs" in metrics


def test_store_inits_cover_all_state_inputs():
    m = _manifest()
    stores = m["stores"]
    for epn in ("sac_update", "wm_update", "sur_update"):
        for i in m["entrypoints"][epn]["inputs"]:
            if i["name"].startswith("state/"):
                key = i["name"][len("state/"):]
                assert key in stores, f"{epn}: {key} missing from stores"
                assert stores[key]["shape"] == i["shape"]
    # copy-inits reference existing keys
    for k, v in stores.items():
        if v["init"].startswith("copy:"):
            assert v["init"][5:] in stores, k


def test_actor_fwd_shapes():
    m = _manifest()
    ep = m["entrypoints"]["actor_fwd_b1"]
    outs = {o["name"]: o["shape"] for o in ep["outputs"]}
    assert outs["mu"] == [1, 30]
    assert outs["log_std"] == [1, 30]
    assert outs["disc_logits"] == [1, 20]
    assert outs["gates"] == [1, 4]


def test_manifest_hyper_matches_module():
    m = _manifest()
    for k, v in m["hyper"].items():
        got = M.HYPER[k]
        if isinstance(got, tuple):
            got = list(got)
        assert got == v, k


def test_store_inits_have_valid_recipes():
    for k, v in aot.store_inits().items():
        assert v["init"] == "zeros" or v["init"] == "he" \
            or v["init"].startswith("copy:") or v["init"].startswith("const:"), k
