# L1 correctness: Pallas fused-linear kernel vs the pure-jnp oracle.
#
# hypothesis sweeps shapes (ragged, tile-boundary, degenerate) and dtypes;
# every case asserts allclose against ref.py for both activations, and the
# custom VJP is checked against jax's autodiff of the reference.
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.fused_mlp import _pick_blocks, linear
from compile.kernels.ref import gelu_grad_ref, gelu_ref, linear_ref

jax.config.update("jax_enable_x64", False)


def _rand(rng, shape, dtype):
    return jnp.asarray(rng.standard_normal(shape), dtype)


def _tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 else dict(
        rtol=2e-5, atol=2e-5
    )


@settings(max_examples=40, deadline=None)
@given(
    m=st.integers(1, 300),
    k=st.integers(1, 96),
    n=st.integers(1, 300),
    act=st.sampled_from(["none", "gelu"]),
    seed=st.integers(0, 2 ** 16),
)
def test_kernel_matches_ref_f32(m, k, n, act, seed):
    rng = np.random.default_rng(seed)
    x, w, b = _rand(rng, (m, k), jnp.float32), _rand(rng, (k, n), jnp.float32), \
        _rand(rng, (n,), jnp.float32)
    got = linear(x, w, b, act)
    want = linear_ref(x, w, b, act)
    assert got.shape == (m, n)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), **_tol(jnp.float32))


@settings(max_examples=15, deadline=None)
@given(
    m=st.sampled_from([1, 8, 64, 129]),
    k=st.sampled_from([52, 82, 256]),
    n=st.sampled_from([1, 30, 128, 256]),
    act=st.sampled_from(["none", "gelu"]),
)
def test_kernel_matches_ref_bf16(m, k, n, act):
    rng = np.random.default_rng(m * 1000 + k * 10 + n)
    x = _rand(rng, (m, k), jnp.bfloat16)
    w = _rand(rng, (k, n), jnp.bfloat16)
    b = _rand(rng, (n,), jnp.bfloat16)
    got = linear(x, w, b, act)
    want = linear_ref(x, w, b, act)
    assert got.dtype == jnp.bfloat16
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32), **_tol(jnp.bfloat16)
    )


@pytest.mark.parametrize("act", ["none", "gelu"])
@pytest.mark.parametrize("shape", [(3, 52, 7), (64, 256, 256), (17, 82, 1)])
def test_kernel_vjp_matches_ref(shape, act):
    m, k, n = shape
    rng = np.random.default_rng(0)
    x, w, b = _rand(rng, (m, k), jnp.float32), _rand(rng, (k, n), jnp.float32), \
        _rand(rng, (n,), jnp.float32)
    f_ker = lambda x, w, b: jnp.sum(linear(x, w, b, act) ** 2)
    f_ref = lambda x, w, b: jnp.sum(linear_ref(x, w, b, act) ** 2)
    g_ker = jax.grad(f_ker, argnums=(0, 1, 2))(x, w, b)
    g_ref = jax.grad(f_ref, argnums=(0, 1, 2))(x, w, b)
    for a, r in zip(g_ker, g_ref):
        np.testing.assert_allclose(np.asarray(a), np.asarray(r), rtol=1e-3, atol=1e-3)


def test_gelu_grad_is_derivative_of_gelu():
    x = jnp.linspace(-4, 4, 101, dtype=jnp.float32)
    want = jax.vmap(jax.grad(lambda v: gelu_ref(v)))(x)
    np.testing.assert_allclose(np.asarray(gelu_grad_ref(x)), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


def test_block_picker_respects_budget_and_alignment():
    for m, n, k in [(1, 1, 1), (256, 256, 256), (7, 300, 82), (4096, 4096, 512)]:
        bm, bn = _pick_blocks(m, n, k)
        assert bm % 8 == 0 and bn % 128 == 0
        assert 4 * (bm * k + k * bn + bm * bn) <= 6 * 1024 * 1024 or bm == 8


def test_kernel_under_jit_and_vmap_composition():
    rng = np.random.default_rng(1)
    x = _rand(rng, (16, 52), jnp.float32)
    w = _rand(rng, (52, 30), jnp.float32)
    b = _rand(rng, (30,), jnp.float32)
    jitted = jax.jit(lambda x: linear(x, w, b, "gelu"))
    np.testing.assert_allclose(
        np.asarray(jitted(x)), np.asarray(linear_ref(x, w, b, "gelu")),
        rtol=2e-5, atol=2e-5,
    )
