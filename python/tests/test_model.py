# L2 correctness: actor/critic/world-model/surrogate semantics and the
# full SAC/WM/surrogate update steps (run in-process through the same
# pallas-backed layers that get AOT-lowered).
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M

H = M.HYPER


def _init_net(shapes, seed):
    rng = np.random.default_rng(seed)
    out = {}
    for k, shp in shapes.items():
        if k.startswith("W"):
            fan_in = shp[0]
            out[k] = jnp.asarray(
                rng.standard_normal(shp) * np.sqrt(2.0 / fan_in), jnp.float32
            )
        else:
            out[k] = jnp.zeros(shp, jnp.float32)
    return out


@pytest.fixture(scope="module")
def actor():
    return _init_net(M.actor_shapes(), 0)


@pytest.fixture(scope="module")
def sac_state():
    z = lambda t: jax.tree_util.tree_map(jnp.zeros_like, t)
    actor = _init_net(M.actor_shapes(), 1)
    c1, c2 = _init_net(M.critic_shapes(), 2), _init_net(M.critic_shapes(), 3)
    scalar = jnp.zeros((), jnp.float32)
    return {
        "actor": actor, "actor_m": z(actor), "actor_v": z(actor),
        "c1": c1, "c1_m": z(c1), "c1_v": z(c1),
        "c2": c2, "c2_m": z(c2), "c2_v": z(c2),
        "t1": jax.tree_util.tree_map(jnp.array, c1),
        "t2": jax.tree_util.tree_map(jnp.array, c2),
        "log_alpha": jnp.asarray(np.log(0.2), jnp.float32),
        "la_m": scalar, "la_v": scalar, "step": scalar,
    }


def _batch(B, seed=0):
    rng = np.random.default_rng(seed)
    r = lambda *shp: jnp.asarray(rng.standard_normal(shp), jnp.float32)
    ad = np.zeros((B, 4, 5), np.float32)
    ad[np.arange(B)[:, None], np.arange(4)[None, :], rng.integers(0, 5, (B, 4))] = 1
    return {
        "s": r(B, H["state_dim"]),
        "a": jnp.tanh(r(B, H["act_dim"])),
        "ad": jnp.asarray(ad.reshape(B, 20)),
        "r": r(B),
        "s2": r(B, H["state_dim"]),
        "done": jnp.zeros((B,), jnp.float32),
        "w": jnp.ones((B,), jnp.float32),
        "eps_cur": r(B, H["act_dim"]),
        "eps_next": r(B, H["act_dim"]),
    }


def test_actor_forward_shapes_and_ranges(actor):
    B = 9
    s = jnp.asarray(np.random.default_rng(4).standard_normal((B, 52)), jnp.float32)
    mu, ls, dl, gates = M.actor_forward(actor, s)
    assert mu.shape == (B, 30) and ls.shape == (B, 30)
    assert dl.shape == (B, 20) and gates.shape == (B, 4)
    # Eq 5: log-std clamped to [-20, 2]
    assert float(ls.min()) >= -20.0 and float(ls.max()) <= 2.0
    # MoE gates are a softmax (Eq 54)
    np.testing.assert_allclose(np.asarray(gates.sum(-1)), np.ones(B), rtol=1e-5)
    # expert means are tanh-bounded so the mixture mean is too (Eq 4)
    assert float(jnp.abs(mu).max()) <= 1.0


def test_squashed_sample_bounds_and_logprob(actor):
    B = 33
    rng = np.random.default_rng(5)
    s = jnp.asarray(rng.standard_normal((B, 52)), jnp.float32)
    mu, ls, _, _ = M.actor_forward(actor, s)
    eps = jnp.asarray(rng.standard_normal((B, 30)), jnp.float32)
    a, logp = M.sample_squashed(mu, ls, eps)
    # tanh may saturate to exactly +/-1.0 in f32; never beyond
    assert float(jnp.abs(a).max()) <= 1.0
    assert bool(jnp.all(jnp.isfinite(logp)))
    # zero-noise sample recovers tanh(mu)
    a0, _ = M.sample_squashed(mu, ls, jnp.zeros_like(eps))
    np.testing.assert_allclose(np.asarray(a0), np.asarray(jnp.tanh(mu)), rtol=1e-5)


def test_critic_forward_shape(sac_state):
    B = 5
    rng = np.random.default_rng(6)
    s = jnp.asarray(rng.standard_normal((B, 52)), jnp.float32)
    a = jnp.asarray(rng.standard_normal((B, 30)), jnp.float32)
    q = M.critic_forward(sac_state["c1"], s, a)
    assert q.shape == (B,)


def test_wm_residual_prediction_is_near_identity_at_init():
    wm = {k: jnp.zeros(v, jnp.float32) for k, v in M.wm_shapes().items()}
    B = 4
    rng = np.random.default_rng(7)
    s = jnp.asarray(rng.standard_normal((B, 52)), jnp.float32)
    a = jnp.asarray(rng.standard_normal((B, 30)), jnp.float32)
    # zero-init world model predicts delta 0 -> identity (Eq 69 residual)
    np.testing.assert_allclose(np.asarray(M.wm_forward(wm, s, a)), np.asarray(s),
                               atol=1e-6)


def test_sac_update_moves_params_and_targets_slowly(sac_state):
    B = 32  # small batch for test speed; lowered artifact uses 256
    out = M.sac_update({"state": sac_state, "batch": _batch(B)})
    st2, metrics = out["state"], out["metrics"]
    # params moved
    dw = float(jnp.abs(st2["actor"]["W1"] - sac_state["actor"]["W1"]).max())
    assert dw > 0.0
    # Polyak targets moved by ~tau of the online delta (Eq 46 targets)
    dt = float(jnp.abs(st2["t1"]["Wa"] - sac_state["t1"]["Wa"]).max())
    dq = float(jnp.abs(st2["c1"]["Wa"] - sac_state["c1"]["Wa"]).max())
    assert dt < dq
    assert metrics["td_abs"].shape == (B,)
    assert bool(jnp.all(jnp.isfinite(metrics["td_abs"])))
    for k in ("critic_loss", "actor_loss", "alpha_loss", "alpha", "entropy"):
        assert np.isfinite(float(metrics[k])), k
    assert float(st2["step"]) == 1.0


def test_sac_update_respects_per_weights(sac_state):
    B = 16
    b = _batch(B)
    zero_w = dict(b, w=jnp.zeros((B,), jnp.float32))
    out = M.sac_update({"state": sac_state, "batch": zero_w})
    # zero importance weights => critic gradient is zero => critic unchanged
    np.testing.assert_allclose(
        np.asarray(out["state"]["c1"]["Wa"]), np.asarray(sac_state["c1"]["Wa"]),
        atol=1e-7,
    )


def test_wm_update_reduces_loss():
    st = {
        "wm": _init_net(M.wm_shapes(), 8),
        "wm_m": {k: jnp.zeros(v, jnp.float32) for k, v in M.wm_shapes().items()},
        "wm_v": {k: jnp.zeros(v, jnp.float32) for k, v in M.wm_shapes().items()},
        "step": jnp.zeros((), jnp.float32),
    }
    rng = np.random.default_rng(9)
    batch = {
        "s": jnp.asarray(rng.standard_normal((64, 52)), jnp.float32),
        "a": jnp.asarray(rng.standard_normal((64, 30)), jnp.float32),
    }
    batch["s2"] = batch["s"] + 0.05  # constant delta: learnable fast
    step = jax.jit(M.wm_update)
    losses = []
    for _ in range(400):
        out = step({"state": st, "batch": batch})
        st = out["state"]
        losses.append(float(out["metrics"]["loss"]))
    assert losses[-1] < losses[0] * 0.5, losses[:3] + losses[-3:]


def test_sur_update_reduces_loss():
    st = {
        "sur": _init_net(M.sur_shapes(), 10),
        "sur_m": {k: jnp.zeros(v, jnp.float32) for k, v in M.sur_shapes().items()},
        "sur_v": {k: jnp.zeros(v, jnp.float32) for k, v in M.sur_shapes().items()},
        "step": jnp.zeros((), jnp.float32),
    }
    rng = np.random.default_rng(11)
    batch = {
        "s": jnp.asarray(rng.standard_normal((64, 52)), jnp.float32),
        "a": jnp.asarray(rng.standard_normal((64, 30)), jnp.float32),
        "ppa": jnp.asarray(np.tile([0.5, -0.2, 0.1], (64, 1)), jnp.float32),
    }
    step = jax.jit(M.sur_update)
    losses = []
    for _ in range(400):
        out = step({"state": st, "batch": batch})
        st = out["state"]
        losses.append(float(out["metrics"]["loss"]))
    assert losses[-1] < losses[0] * 0.5


def test_hyper_matches_paper_tables():
    # Table 2/3/6 headline dimensions
    assert H["state_dim"] == 52 and H["full_state_dim"] == 73
    assert H["act_dim"] == 30 and H["disc_dim"] == 20
    assert H["hidden"] == 256 and H["batch"] == 256
    assert H["target_entropy"] == -30.0
    assert H["tau"] == 0.005 and H["gamma"] == 0.99
    assert H["wm_hidden"] == (128, 64) and H["mpc_batch"] == 64
